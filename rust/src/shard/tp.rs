//! Head-sharded tensor parallelism: one model, its attention heads split
//! across runners.
//!
//! Partition: each shard owns a contiguous head range of *every* layer
//! ([`partition_heads`]) and computes only those heads' attention through
//! `kernel::prefill_head_range` / the per-head `step` path
//! (`NativeLm::{prefill_sharded, step_sharded}`).  Everything else —
//! embeddings, layernorms, FFN, readout — is replicated bit-identically
//! on every shard.  Per layer, each shard contributes a *partial*
//! attention output (its head stripes of the masked concat times `wo`);
//! a [`TpCombine`] implementation produces the world sum, which every
//! shard adds into its replicated residual.
//!
//! Determinism: the world sum is always formed in shard-index order
//! (f32 addition does not commute bitwise), and all shards receive the
//! *same* summed bytes, so their residuals, logits, and sampled tokens
//! are identical — any one shard (the leader, shard 0) can own the token
//! stream.  A TP run is bitwise reproducible against itself and against
//! [`LocalCombine`] (the in-process reference), but *not* against the
//! unsharded model: splitting the `concat · wo` matmul reassociates the
//! inner-product sums.  World size 1 *is* bitwise-identical to the
//! unsharded path (one partial, identity sum) — pinned by tests.

use std::collections::HashMap;
use std::ops::Range;
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context};

use crate::infer::{GenRequest, NativeLm};
use crate::util::rng::Pcg;

use super::mux::Mux;
use super::proto::{decode_tp_vec, encode_tp_vec, Frame, FrameKind};

/// Contiguous near-equal head ranges: the first `heads % world` shards
/// get one extra head.  Every range is non-empty, so `world` must not
/// exceed `heads`.
pub fn partition_heads(heads: usize, world: usize) -> Vec<Range<usize>> {
    assert!(world > 0 && world <= heads, "world {world} must be in 1..={heads}");
    let base = heads / world;
    let extra = heads % world;
    let mut ranges = Vec::with_capacity(world);
    let mut start = 0;
    for s in 0..world {
        let len = base + usize::from(s < extra);
        ranges.push(start..start + len);
        start += len;
    }
    ranges
}

/// World-sum exchange for one shard's sequence of partial attention
/// outputs.  Implementations must return the shard-index-ordered sum of
/// all shards' partials for the same call position.
pub trait TpCombine {
    fn combine(&mut self, layer: usize, partial: Vec<f32>) -> anyhow::Result<Vec<f32>>;
}

/// Outcome of a sharded generation run (leader and followers compute
/// identical values).
pub struct TpRun {
    pub generated: Vec<u32>,
    pub prompt_len: usize,
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub ttft_secs: f64,
    pub last_logits: Vec<f32>,
}

/// Run one generation request on one shard, mirroring `DecodeSession`'s
/// sample/step order exactly (sample from last logits, push, step even
/// on the final token).  `on_token` fires per generated token — the
/// leader streams from it; followers pass a no-op.
pub fn run_tp_session(
    model: &NativeLm,
    range: Range<usize>,
    req: &GenRequest,
    combine: &mut dyn TpCombine,
    on_token: &mut dyn FnMut(u32) -> anyhow::Result<()>,
) -> anyhow::Result<TpRun> {
    ensure!(!req.prompt.is_empty(), "prompt must contain at least BOS");
    let mut states = model.new_states();
    let mut cb = |li: usize, partial: Vec<f32>| combine.combine(li, partial);
    let t0 = Instant::now();
    let logits = model.prefill_sharded(&req.prompt, Some(&mut states), range.clone(), &mut cb)?;
    let prefill_secs = t0.elapsed().as_secs_f64();
    let mut last = logits.row(req.prompt.len() - 1).to_vec();
    let mut rng = Pcg::seeded(req.seed);
    let mut tokens = req.prompt.clone();
    let mut generated = Vec::with_capacity(req.max_new_tokens);
    let mut decode_secs = 0.0;
    let mut ttft_secs = prefill_secs;
    for i in 0..req.max_new_tokens {
        let ts = Instant::now();
        let tok = req.policy.sample(&last, &mut rng) as u32;
        tokens.push(tok);
        generated.push(tok);
        if i == 0 {
            ttft_secs = t0.elapsed().as_secs_f64();
        }
        on_token(tok)?;
        let pos = tokens.len() - 1;
        last = model.step_sharded(tok, pos, &mut states, range.clone(), &mut cb)?;
        decode_secs += ts.elapsed().as_secs_f64();
    }
    Ok(TpRun {
        generated,
        prompt_len: req.prompt.len(),
        prefill_secs,
        decode_secs,
        ttft_secs,
        last_logits: last,
    })
}

// ------------------------------------------------------- LocalCombine

struct WorldState {
    /// round -> per-shard partials collected so far.
    pending: HashMap<u64, Vec<Option<Vec<f32>>>>,
    /// round -> (world sum, shards that have consumed it).
    results: HashMap<u64, (Arc<Vec<f32>>, usize)>,
}

struct WorldInner {
    world: usize,
    state: Mutex<WorldState>,
    cv: Condvar,
}

/// In-process reference combiner: `world(n)` hands one handle per shard
/// to `n` threads stepping the same request in lock-step.  Rounds are
/// keyed by each handle's private call counter — all shards make the
/// same sequence of combine calls, so counters align without any global
/// barrier state to reset (a fast shard entering round `r+1` while a
/// slow one is still summing round `r` just parks both rounds in the
/// maps independently).
pub struct LocalCombine {
    inner: Arc<WorldInner>,
    shard: usize,
    round: u64,
    /// Deadlock guard for tests: a peer that died mid-run would
    /// otherwise park us on the condvar forever.
    timeout: Duration,
}

impl LocalCombine {
    pub fn world(n: usize) -> Vec<LocalCombine> {
        assert!(n > 0);
        let inner = Arc::new(WorldInner {
            world: n,
            state: Mutex::new(WorldState { pending: HashMap::new(), results: HashMap::new() }),
            cv: Condvar::new(),
        });
        (0..n)
            .map(|shard| LocalCombine {
                inner: Arc::clone(&inner),
                shard,
                round: 0,
                timeout: Duration::from_secs(60),
            })
            .collect()
    }
}

impl TpCombine for LocalCombine {
    fn combine(&mut self, _layer: usize, partial: Vec<f32>) -> anyhow::Result<Vec<f32>> {
        let round = self.round;
        self.round += 1;
        let world = self.inner.world;
        let mut st = self.inner.state.lock().unwrap();
        {
            let entry = st.pending.entry(round).or_insert_with(|| vec![None; world]);
            ensure!(entry[self.shard].is_none(), "shard {} double-submitted round {round}", self.shard);
            entry[self.shard] = Some(partial);
        }
        if st.pending[&round].iter().all(|p| p.is_some()) {
            // Last arriver sums in shard-index order — the order every
            // combiner implementation must honor.
            let parts = st.pending.remove(&round).unwrap();
            let mut iter = parts.into_iter().map(Option::unwrap);
            let mut sum = iter.next().unwrap();
            for p in iter {
                ensure!(p.len() == sum.len(), "partial length mismatch in round {round}");
                for (s, v) in sum.iter_mut().zip(&p) {
                    *s += v;
                }
            }
            st.results.insert(round, (Arc::new(sum), 0));
            self.inner.cv.notify_all();
        }
        let deadline = Instant::now() + self.timeout;
        loop {
            if let Some((sum, taken)) = st.results.get_mut(&round) {
                let out = (**sum).clone();
                *taken += 1;
                if *taken == world {
                    st.results.remove(&round);
                }
                return Ok(out);
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                bail!("LocalCombine timed out waiting for round {round}");
            }
            let (guard, _) = self.inner.cv.wait_timeout(st, left).unwrap();
            st = guard;
        }
    }
}

// --------------------------------------------------------- IpcCombine

/// Runner-side combiner over the gateway connection: sends this shard's
/// partial as a `TpPartial` frame and blocks (bounded) for the
/// gateway-summed `TpCombined` answer on the request's stream.
pub struct IpcCombine<'a> {
    pub mux: &'a Mux,
    pub rx: &'a Receiver<Frame>,
    pub stream: u64,
    pub timeout: Duration,
}

impl TpCombine for IpcCombine<'_> {
    fn combine(&mut self, layer: usize, partial: Vec<f32>) -> anyhow::Result<Vec<f32>> {
        self.mux
            .send(&Frame::new(FrameKind::TpPartial, self.stream, encode_tp_vec(layer as u32, &partial)))
            .context("sending TpPartial")?;
        let f = self
            .rx
            .recv_timeout(self.timeout)
            .context("waiting for TpCombined (gateway gone?)")?;
        match f.kind {
            FrameKind::TpCombined => {
                let (l, data) = decode_tp_vec(&f.payload)?;
                ensure!(l as usize == layer, "TpCombined for layer {l}, expected {layer}");
                Ok(data)
            }
            FrameKind::Cancel => bail!("request cancelled by gateway"),
            other => bail!("unexpected {other:?} frame on TP stream"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::Mechanism;
    use crate::infer::{DecodeSession, LmConfig, SamplePolicy};
    use std::thread;

    fn model() -> NativeLm {
        let cfg = LmConfig { vocab: 64, d_model: 32, layers: 2, heads: 4, ff_mult: 2, seed: 3 };
        NativeLm::new(cfg, Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true })
    }

    fn req() -> GenRequest {
        GenRequest {
            prompt: vec![0, 5, 9, 21, 2],
            max_new_tokens: 8,
            policy: SamplePolicy::TopP { p: 0.9, temperature: 0.8 },
            seed: 99,
        }
    }

    #[test]
    fn partition_is_contiguous_and_covers() {
        for heads in 1..=8 {
            for world in 1..=heads {
                let ranges = partition_heads(heads, world);
                assert_eq!(ranges.len(), world);
                assert_eq!(ranges[0].start, 0);
                assert_eq!(ranges[world - 1].end, heads);
                for w in 1..world {
                    assert_eq!(ranges[w].start, ranges[w - 1].end);
                    assert!(!ranges[w].is_empty());
                }
            }
        }
    }

    #[test]
    fn world_one_is_bitwise_identical_to_decode_session() {
        let m = model();
        let mut session = DecodeSession::new(&m, 0, req());
        session.run_to_completion(&m);
        let mut combine = LocalCombine::world(1).pop().unwrap();
        let run =
            run_tp_session(&m, 0..m.cfg.heads, &req(), &mut combine, &mut |_| Ok(())).unwrap();
        assert_eq!(run.generated, session.generated());
        let want: Vec<u32> = session.snapshot().last_logits.iter().map(|v| v.to_bits()).collect();
        let got: Vec<u32> = run.last_logits.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want, "world-1 TP must be the unsharded computation");
    }

    #[test]
    fn two_shards_agree_bitwise_and_match_full_model_closely() {
        let m = Arc::new(model());
        let ranges = partition_heads(m.cfg.heads, 2);
        let combines = LocalCombine::world(2);
        let mut handles = Vec::new();
        for (range, mut combine) in ranges.into_iter().zip(combines) {
            let m = Arc::clone(&m);
            handles.push(thread::spawn(move || {
                run_tp_session(&m, range, &req(), &mut combine, &mut |_| Ok(())).unwrap()
            }));
        }
        let runs: Vec<TpRun> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // Cross-shard agreement is exact: both added the same combined
        // bytes into the same replicated residual.
        assert_eq!(runs[0].generated, runs[1].generated);
        let a: Vec<u32> = runs[0].last_logits.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = runs[1].last_logits.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "shards diverged — combine is not giving both the same bytes");
        // Against the unsharded model the match is close, not bitwise
        // (the split reassociates the wo matmul's inner sums).
        let mut session = DecodeSession::new(&m, 0, req());
        session.run_to_completion(&m);
        let full = session.snapshot().last_logits;
        for (x, y) in runs[0].last_logits.iter().zip(&full) {
            let tol = 1e-3 * (1.0 + x.abs().max(y.abs()));
            assert!((x - y).abs() <= tol, "TP logit {x} vs full {y}");
        }
    }

    #[test]
    fn dead_shard_times_out_instead_of_hanging() {
        let m = model();
        let mut combine = LocalCombine::world(2).pop().unwrap();
        combine.timeout = Duration::from_millis(100);
        // The other shard never shows up: combine must error out.
        let err = run_tp_session(&m, 2..4, &req(), &mut combine, &mut |_| Ok(()));
        assert!(err.is_err());
    }
}
