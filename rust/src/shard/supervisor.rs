//! Runner process supervision: spawn, monitor, respawn, never die.
//!
//! The supervisor owns N slots, one per runner process.  Each slot
//! holds the child handle, the mux over its Unix-socket connection, and
//! its health state.  A monitor thread heartbeats every slot
//! ([`SupervisorConfig::heartbeat_ms`]): any inbound frame refreshes
//! `last_seen`, a `Ping` goes out each tick, and a runner is declared
//! dead when its process has exited, its connection hit EOF, or its
//! silence exceeds the staleness window.  Death is graceful
//! degradation, not gateway death:
//!
//! ```text
//!   healthy --(EOF | exit | stale)--> dead: ring.remove(id),
//!       mux torn down (=> every in-flight stream on it disconnects,
//!       the gateway answers those requests with a retriable error)
//!   dead --(respawn ok: fresh socket, Hello)--> healthy: ring.add(id)
//!   dead --(respawn fails)--> dead (retried next tick; the gateway
//!       keeps serving on the surviving runners)
//! ```
//!
//! Respawned replicas rebuild from the same model args (checkpoint or
//! config+seed) the originals got, so a retried request is byte-identical
//! to what the dead runner would have produced — determinism makes crash
//! recovery invisible to clients beyond the one retriable error.

use std::os::unix::net::UnixListener;
use std::path::PathBuf;
use std::process::{Child, Command};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{bail, Context};

use super::mux::Mux;
use super::proto::{decode_hello, encode_generate, Frame, FrameKind};
use super::ring::HashRing;
use super::tp::partition_heads;
use crate::infer::GenRequest;
use crate::metrics::ServeCounters;

#[derive(Clone, Debug)]
pub struct SupervisorConfig {
    pub runners: usize,
    /// Binary to exec for runners; the gateway's own executable in
    /// production (`psf runner` is a hidden subcommand), overridden by
    /// tests/benches with `env!("CARGO_BIN_EXE_psf")`.
    pub runner_exe: PathBuf,
    /// Model flags forwarded verbatim to every runner (`--checkpoint p`
    /// or `--mech m --d-model d ...`) — identical args + identical seed
    /// is what makes replicas and respawns byte-equivalent.
    pub model_args: Vec<String>,
    pub runner_workers: usize,
    pub slice_tokens: usize,
    pub max_resident: usize,
    pub queue_cap: usize,
    pub cache_mb: usize,
    /// Exec-pool threads per runner; 0 lets `psf runner` auto-size.
    pub threads_per_runner: usize,
    pub heartbeat_ms: u64,
    pub connect_timeout_ms: u64,
    /// Head-sharded tensor parallelism instead of data-parallel replicas.
    pub tp: bool,
    /// Model head count (needed to partition in TP mode).
    pub heads: usize,
    pub socket_dir: PathBuf,
    /// When set, each runner gets `--trace <base>.runner<id>` so it
    /// exports its own span trace; the gateway merges those files into
    /// the base trace at shutdown (one Perfetto timeline).
    pub trace_base: Option<PathBuf>,
    /// When set, each runner gets `--incident <base>.runner<id>` (its own
    /// incident-dump path), the gateway's own dump embeds those files,
    /// and a runner death triggers a gateway-side incident dump.
    pub incident_base: Option<PathBuf>,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            runners: 2,
            runner_exe: std::env::current_exe().unwrap_or_else(|_| PathBuf::from("psf")),
            model_args: Vec::new(),
            runner_workers: 2,
            slice_tokens: 4,
            max_resident: 8,
            queue_cap: 64,
            cache_mb: 64,
            threads_per_runner: 0,
            heartbeat_ms: 500,
            connect_timeout_ms: 30_000,
            tp: false,
            heads: 0,
            socket_dir: std::env::temp_dir(),
            trace_base: None,
            incident_base: None,
        }
    }
}

struct Slot {
    id: u32,
    head_start: usize,
    head_end: usize,
    socket: PathBuf,
    child: Option<Child>,
    mux: Option<Arc<Mux>>,
    inbound: Option<Receiver<Frame>>,
    healthy: bool,
    last_seen: Instant,
    respawns: u64,
}

/// An open request stream on a runner connection: receive frames from
/// `rx`; drop closes the stream registration.
pub struct OpenStream {
    pub runner: u32,
    pub stream: u64,
    pub rx: Receiver<Frame>,
    mux: Arc<Mux>,
}

impl OpenStream {
    /// Ask the runner to abandon this request (best-effort).
    pub fn cancel(&self) {
        let _ = self.mux.send(&Frame::new(FrameKind::Cancel, self.stream, Vec::new()));
    }

    /// Send the gateway-side answer in a TP exchange.
    pub fn send(&self, frame: &Frame) -> std::io::Result<()> {
        self.mux.send(frame)
    }
}

impl Drop for OpenStream {
    fn drop(&mut self) {
        self.mux.close_stream(self.stream);
    }
}

pub struct Supervisor {
    cfg: SupervisorConfig,
    slots: Vec<Mutex<Slot>>,
    ring: Mutex<HashRing>,
    stop: Arc<AtomicBool>,
    monitor: Mutex<Option<JoinHandle<()>>>,
    respawn_total: AtomicU64,
    ever_degraded: AtomicBool,
    /// Optional counters sink (the sharded gateway's) for the heartbeat
    /// RTT histogram.
    counters: Mutex<Option<Arc<ServeCounters>>>,
}

impl Supervisor {
    /// Spawn every runner, wait for their Hellos, build the ring, and
    /// start the monitor.  Startup is strict (any runner failing to come
    /// up is an error); post-startup failures degrade instead.
    pub fn start(cfg: SupervisorConfig) -> anyhow::Result<Arc<Supervisor>> {
        anyhow::ensure!(cfg.runners > 0, "need at least one runner");
        let ranges = if cfg.tp {
            anyhow::ensure!(
                cfg.heads >= cfg.runners,
                "tensor parallelism needs heads >= runners ({} < {})",
                cfg.heads,
                cfg.runners
            );
            partition_heads(cfg.heads, cfg.runners)
        } else {
            (0..cfg.runners).map(|_| 0..0).collect()
        };
        let slots = ranges
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                Mutex::new(Slot {
                    id: i as u32,
                    head_start: r.start,
                    head_end: r.end,
                    socket: cfg
                        .socket_dir
                        .join(format!("psf-runner-{}-{i}.sock", std::process::id())),
                    child: None,
                    mux: None,
                    inbound: None,
                    healthy: false,
                    last_seen: Instant::now(),
                    respawns: 0,
                })
            })
            .collect();
        let sup = Arc::new(Supervisor {
            cfg,
            slots,
            ring: Mutex::new(HashRing::new()),
            stop: Arc::new(AtomicBool::new(false)),
            monitor: Mutex::new(None),
            respawn_total: AtomicU64::new(0),
            ever_degraded: AtomicBool::new(false),
            counters: Mutex::new(None),
        });
        for slot in &sup.slots {
            let mut slot = slot.lock().unwrap();
            sup.spawn_slot(&mut slot)
                .with_context(|| format!("starting runner {}", slot.id))?;
            sup.ring.lock().unwrap().add(slot.id);
        }
        let m = Arc::clone(&sup);
        let handle = thread::Builder::new()
            .name("shard-supervisor".into())
            .spawn(move || m.monitor_loop())?;
        *sup.monitor.lock().unwrap() = Some(handle);
        Ok(sup)
    }

    fn spawn_slot(&self, slot: &mut Slot) -> anyhow::Result<()> {
        let _ = std::fs::remove_file(&slot.socket);
        let listener = UnixListener::bind(&slot.socket)
            .with_context(|| format!("binding {}", slot.socket.display()))?;
        listener.set_nonblocking(true)?;
        let mut cmd = Command::new(&self.cfg.runner_exe);
        cmd.arg("runner")
            .arg("--socket")
            .arg(&slot.socket)
            .args(["--id", &slot.id.to_string()])
            .args(["--workers", &self.cfg.runner_workers.to_string()])
            .args(["--slice", &self.cfg.slice_tokens.to_string()])
            .args(["--resident", &self.cfg.max_resident.to_string()])
            .args(["--queue-cap", &self.cfg.queue_cap.to_string()])
            .args(["--cache-mb", &self.cfg.cache_mb.to_string()])
            .args(["--threads", &self.cfg.threads_per_runner.to_string()])
            .args(&self.cfg.model_args);
        if slot.head_end > slot.head_start {
            cmd.args(["--head-start", &slot.head_start.to_string()])
                .args(["--head-end", &slot.head_end.to_string()]);
        }
        if let Some(base) = &self.cfg.trace_base {
            cmd.arg("--trace").arg(format!("{}.runner{}", base.display(), slot.id));
        }
        if let Some(base) = &self.cfg.incident_base {
            cmd.arg("--incident").arg(format!("{}.runner{}", base.display(), slot.id));
        }
        let mut child = cmd.spawn().context("spawning runner process")?;

        // Nonblocking accept with a deadline: a runner that never
        // connects must not wedge the supervisor.
        let deadline = Instant::now() + Duration::from_millis(self.cfg.connect_timeout_ms);
        let conn = loop {
            match listener.accept() {
                Ok((conn, _)) => break conn,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if let Ok(Some(status)) = child.try_wait() {
                        bail!("runner {} exited before connecting: {status}", slot.id);
                    }
                    if Instant::now() >= deadline {
                        let _ = child.kill();
                        let _ = child.wait();
                        bail!("runner {} did not connect within timeout", slot.id);
                    }
                    thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    let _ = child.kill();
                    let _ = child.wait();
                    return Err(e).context("accepting runner connection");
                }
            }
        };
        conn.set_nonblocking(false)?;
        let (tx, rx) = channel();
        let mux = Mux::start(conn, tx)?;

        // First frame must be the Hello announcing identity.
        let hello_deadline = Duration::from_millis(self.cfg.connect_timeout_ms);
        let frame = rx
            .recv_timeout(hello_deadline)
            .map_err(|_| anyhow::anyhow!("runner {} sent no Hello", slot.id))?;
        anyhow::ensure!(
            frame.kind == FrameKind::Hello,
            "runner {} opened with {:?}, expected Hello",
            slot.id,
            frame.kind
        );
        let hello = decode_hello(&frame.payload)?;
        anyhow::ensure!(
            hello.runner_id == slot.id,
            "socket {} answered as runner {}, expected {}",
            slot.socket.display(),
            hello.runner_id,
            slot.id
        );

        slot.child = Some(child);
        slot.mux = Some(mux);
        slot.inbound = Some(rx);
        slot.healthy = true;
        slot.last_seen = Instant::now();
        Ok(())
    }

    fn staleness_window(&self) -> Duration {
        Duration::from_millis((self.cfg.heartbeat_ms * 5).max(2_000))
    }

    fn monitor_loop(&self) {
        while !self.stop.load(Ordering::SeqCst) {
            thread::sleep(Duration::from_millis(self.cfg.heartbeat_ms));
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            for slot in &self.slots {
                let mut slot = slot.lock().unwrap();
                if !slot.healthy {
                    if self.spawn_slot(&mut slot).is_ok() {
                        self.ring.lock().unwrap().add(slot.id);
                        slot.respawns += 1;
                        self.respawn_total.fetch_add(1, Ordering::Relaxed);
                        eprintln!(
                            "psf serve: runner {} respawned (respawn #{})",
                            slot.id, slot.respawns
                        );
                    }
                    continue;
                }
                // Any inbound traffic (Pong, stray frames for closed
                // streams) counts as liveness.
                let mut saw_traffic = false;
                if let Some(rx) = slot.inbound.as_ref() {
                    while rx.try_recv().is_ok() {
                        saw_traffic = true;
                    }
                }
                if saw_traffic {
                    slot.last_seen = Instant::now();
                }
                let exited = slot
                    .child
                    .as_mut()
                    .map_or(true, |c| matches!(c.try_wait(), Ok(Some(_)) | Err(_)));
                let mux_dead = slot.mux.as_ref().map_or(true, |m| !m.is_alive());
                let stale = slot.last_seen.elapsed() > self.staleness_window();
                if exited || mux_dead || stale {
                    self.mark_dead(&mut slot, if exited { "exited" } else if mux_dead { "connection lost" } else { "heartbeat stale" });
                    continue;
                }
                // Heartbeat probe.  Wait briefly for the Pong right here:
                // pairing it with the *next* tick's drain would record the
                // tick period, not the round trip.  The bound is well under
                // the tick period, so the monitor cannot fall behind.
                let pong_rtt = match (slot.mux.as_ref(), slot.inbound.as_ref()) {
                    (Some(mux), Some(rx))
                        if mux.send(&Frame::control(FrameKind::Ping)).is_ok() =>
                    {
                        let t0 = Instant::now();
                        let budget = Duration::from_millis(50);
                        let mut rtt = None;
                        while rtt.is_none() && t0.elapsed() < budget {
                            match rx.recv_timeout(budget.saturating_sub(t0.elapsed())) {
                                Ok(f) if f.kind == FrameKind::Pong => rtt = Some(t0.elapsed()),
                                Ok(_) => {} // stray stream traffic; keep waiting
                                Err(_) => break,
                            }
                        }
                        rtt
                    }
                    _ => None,
                };
                if let Some(rtt) = pong_rtt {
                    slot.last_seen = Instant::now();
                    if let Some(c) = self.counters.lock().unwrap().as_ref() {
                        c.ipc_rtt.observe(rtt.as_secs_f64());
                    }
                }
            }
        }
    }

    fn mark_dead(&self, slot: &mut Slot, why: &str) {
        eprintln!("psf serve: runner {} is down ({why}) — degraded, respawning", slot.id);
        self.ever_degraded.store(true, Ordering::SeqCst);
        slot.healthy = false;
        self.ring.lock().unwrap().remove(slot.id);
        if let Some(mux) = slot.mux.take() {
            // Cascades Disconnected to every in-flight stream on this
            // runner: the gateway answers them with a retriable error.
            mux.shutdown();
        }
        slot.inbound = None;
        if let Some(mut child) = slot.child.take() {
            let _ = child.kill();
            let _ = child.wait();
        }
        // A SIGKILLed runner can't write its own incident file, so the
        // gateway-side dump is the durable record of the death (it embeds
        // whatever per-runner files do exist).
        if crate::obs::incident::configured() {
            let _ = crate::obs::incident::dump(&format!("runner {} died: {why}", slot.id));
        }
    }

    // ------------------------------------------------------ gateway API

    /// Sink for supervisor-side histograms (heartbeat IPC RTT).  The
    /// sharded gateway passes its own counters in.
    pub fn set_counters(&self, c: Arc<ServeCounters>) {
        *self.counters.lock().unwrap() = Some(c);
    }

    /// Per-runner trace files this configuration makes runners write —
    /// what the gateway merges into one timeline at shutdown.
    pub fn runner_trace_paths(&self) -> Vec<PathBuf> {
        match &self.cfg.trace_base {
            Some(base) => (0..self.slots.len())
                .map(|i| PathBuf::from(format!("{}.runner{i}", base.display())))
                .collect(),
            None => Vec::new(),
        }
    }

    /// Per-runner incident files this configuration makes runners write —
    /// the gateway-side incident dump embeds them.
    pub fn runner_incident_paths(&self) -> Vec<PathBuf> {
        match &self.cfg.incident_base {
            Some(base) => (0..self.slots.len())
                .map(|i| PathBuf::from(format!("{}.runner{i}", base.display())))
                .collect(),
            None => Vec::new(),
        }
    }

    /// Route a cache-key hash to a healthy runner.
    pub fn route(&self, hash: u64) -> Option<u32> {
        self.ring.lock().unwrap().route(hash)
    }

    /// Open a request stream on `runner` and send the Generate frame.
    /// `trace_id` crosses the wire so runner spans stitch into the
    /// request's trace (0 = untraced).
    pub fn open_generate(
        &self,
        runner: u32,
        req: &GenRequest,
        trace_id: u64,
    ) -> anyhow::Result<OpenStream> {
        self.open_with(runner, FrameKind::Generate, req, trace_id)
    }

    /// Open a TP request stream on every runner (slot order), sending
    /// each the same request.  TP needs the full world, so any unhealthy
    /// runner is an error.
    pub fn tp_streams(&self, req: &GenRequest, trace_id: u64) -> anyhow::Result<Vec<OpenStream>> {
        self.slots
            .iter()
            .enumerate()
            .map(|(i, _)| self.open_with(i as u32, FrameKind::TpGenerate, req, trace_id))
            .collect()
    }

    fn open_with(
        &self,
        runner: u32,
        kind: FrameKind,
        req: &GenRequest,
        trace_id: u64,
    ) -> anyhow::Result<OpenStream> {
        let slot = self.slots[runner as usize].lock().unwrap();
        anyhow::ensure!(slot.healthy, "runner {runner} is down");
        let mux = Arc::clone(slot.mux.as_ref().expect("healthy slot has a mux"));
        drop(slot);
        let (stream, rx) = mux.open_stream();
        mux.send(&Frame::new(kind, stream, encode_generate(req, trace_id)))
            .with_context(|| format!("sending request to runner {runner}"))?;
        Ok(OpenStream { runner, stream, rx, mux })
    }

    /// Ask `runner` for its serve counters (JSON object), bounded by
    /// `timeout`.  `None` if the runner is down or slow.
    pub fn fetch_runner_metrics(&self, runner: u32, timeout: Duration) -> Option<String> {
        let mux = {
            let slot = self.slots[runner as usize].lock().unwrap();
            if !slot.healthy {
                return None;
            }
            Arc::clone(slot.mux.as_ref()?)
        };
        let (stream, rx) = mux.open_stream();
        if mux.send(&Frame::new(FrameKind::MetricsReq, stream, Vec::new())).is_err() {
            mux.close_stream(stream);
            return None;
        }
        let reply = rx.recv_timeout(timeout).ok();
        mux.close_stream(stream);
        match reply {
            Some(f) if f.kind == FrameKind::MetricsReply => String::from_utf8(f.payload).ok(),
            _ => None,
        }
    }

    /// (total, healthy) runner counts.
    pub fn health(&self) -> (usize, usize) {
        let healthy =
            self.slots.iter().filter(|s| s.lock().unwrap().healthy).count();
        (self.slots.len(), healthy)
    }

    /// Per-runner (healthy, respawns) snapshot, slot order.
    pub fn runner_states(&self) -> Vec<(bool, u64)> {
        self.slots
            .iter()
            .map(|s| {
                let s = s.lock().unwrap();
                (s.healthy, s.respawns)
            })
            .collect()
    }

    pub fn respawn_count(&self) -> u64 {
        self.respawn_total.load(Ordering::Relaxed)
    }

    pub fn was_ever_degraded(&self) -> bool {
        self.ever_degraded.load(Ordering::SeqCst)
    }

    pub fn is_tp(&self) -> bool {
        self.cfg.tp
    }

    pub fn runners(&self) -> usize {
        self.slots.len()
    }

    /// SIGKILL a runner process (crash-recovery tests and smokes; the
    /// monitor detects and respawns it like any real crash).
    pub fn kill_runner(&self, runner: u32) {
        let mut slot = self.slots[runner as usize].lock().unwrap();
        if let Some(child) = slot.child.as_mut() {
            let _ = child.kill();
        }
    }

    /// Stop the monitor, ask every runner to drain, and reap them
    /// (5s of grace, then SIGKILL).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.monitor.lock().unwrap().take() {
            let _ = h.join();
        }
        for slot in &self.slots {
            let mut slot = slot.lock().unwrap();
            if let Some(mux) = slot.mux.take() {
                let _ = mux.send(&Frame::control(FrameKind::Shutdown));
            }
            if let Some(mut child) = slot.child.take() {
                let deadline = Instant::now() + Duration::from_secs(5);
                loop {
                    match child.try_wait() {
                        Ok(Some(_)) => break,
                        Ok(None) if Instant::now() < deadline => {
                            thread::sleep(Duration::from_millis(20));
                        }
                        _ => {
                            let _ = child.kill();
                            let _ = child.wait();
                            break;
                        }
                    }
                }
            }
            let _ = std::fs::remove_file(&slot.socket);
            slot.healthy = false;
        }
    }
}
