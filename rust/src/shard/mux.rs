//! Stream multiplexer: many in-flight requests share one Unix-socket
//! connection per runner.
//!
//! Both ends are symmetric: a dedicated reader thread decodes frames
//! off the socket and dispatches each by stream id — registered streams
//! get their own channel, everything else (new work, control traffic)
//! lands on the connection's `inbound` channel.  Writes go through a
//! mutex so concurrent senders cannot interleave frame bytes.
//!
//! Death is a channel property, not a status code: when the socket hits
//! EOF or an I/O error, the reader thread drops every registered sender
//! and the inbound sender, so every `Receiver` immediately observes
//! `Disconnected`.  Callers therefore need no separate liveness poll on
//! the happy path — a dead peer fails every pending `recv` at once,
//! which is what gives in-flight requests their fail-fast retriable
//! error when a runner is SIGKILLed mid-stream.
//!
//! Stream-id discipline: 0 is connection control (Hello/Ping/Pong/
//! Shutdown); the gateway allocates ids >= 1 via [`Mux::open_stream`];
//! runners only ever echo ids they were given, so the two sides cannot
//! collide without a coordination handshake.

use std::collections::HashMap;
use std::io;
use std::net::Shutdown;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread;

use super::proto::Frame;

pub struct Mux {
    writer: Mutex<UnixStream>,
    /// Socket handle the reader owns a clone of; kept for shutdown.
    sock: UnixStream,
    streams: Mutex<HashMap<u64, Sender<Frame>>>,
    alive: Arc<AtomicBool>,
    next_stream: AtomicU64,
}

impl Mux {
    /// Wrap a connected socket.  Frames for unregistered stream ids are
    /// sent to `inbound`; the sender is dropped when the connection dies
    /// so the peer's death is visible as `inbound` disconnecting.
    pub fn start(conn: UnixStream, inbound: Sender<Frame>) -> io::Result<Arc<Mux>> {
        let reader_half = conn.try_clone()?;
        let writer_half = conn.try_clone()?;
        let mux = Arc::new(Mux {
            writer: Mutex::new(writer_half),
            sock: conn,
            streams: Mutex::new(HashMap::new()),
            alive: Arc::new(AtomicBool::new(true)),
            next_stream: AtomicU64::new(1),
        });
        let m = Arc::clone(&mux);
        thread::Builder::new()
            .name("shard-mux-reader".into())
            .spawn(move || m.reader_loop(reader_half, inbound))
            .map_err(|e| io::Error::new(io::ErrorKind::Other, e))?;
        Ok(mux)
    }

    fn reader_loop(&self, mut sock: UnixStream, inbound: Sender<Frame>) {
        loop {
            match Frame::read_from(&mut sock) {
                Ok(Some(frame)) => {
                    let target = self.streams.lock().unwrap().get(&frame.stream).cloned();
                    match target {
                        // A consumer that already hung up is not a
                        // connection error — just drop the frame.
                        Some(tx) => drop(tx.send(frame)),
                        None => {
                            if inbound.send(frame).is_err() {
                                break; // connection owner went away
                            }
                        }
                    }
                }
                Ok(None) | Err(_) => break, // EOF or poisoned wire: connection is dead
            }
        }
        self.alive.store(false, Ordering::SeqCst);
        // Dropping every sender turns peer death into `Disconnected` on
        // all pending receivers at once.
        self.streams.lock().unwrap().clear();
        drop(inbound);
    }

    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    /// Send one frame; serialized against other senders.
    pub fn send(&self, frame: &Frame) -> io::Result<()> {
        if !self.is_alive() {
            return Err(io::Error::new(io::ErrorKind::BrokenPipe, "mux connection is dead"));
        }
        let mut w = self.writer.lock().unwrap();
        frame.write_to(&mut *w)
    }

    /// Allocate a fresh stream id and register a receiver for it.
    pub fn open_stream(&self) -> (u64, Receiver<Frame>) {
        let id = self.next_stream.fetch_add(1, Ordering::SeqCst);
        (id, self.register_stream(id))
    }

    /// Register a receiver for frames addressed to `id` (used by the
    /// runner side, which echoes gateway-assigned ids).
    pub fn register_stream(&self, id: u64) -> Receiver<Frame> {
        let (tx, rx) = channel();
        let stale = {
            let mut streams = self.streams.lock().unwrap();
            let stale = streams.insert(id, tx);
            // Registering against a dead connection must still yield a
            // receiver that reports Disconnected immediately.
            if !self.is_alive() {
                streams.clear();
            }
            stale
        };
        drop(stale);
        rx
    }

    pub fn close_stream(&self, id: u64) {
        self.streams.lock().unwrap().remove(&id);
    }

    /// Tear the connection down: the reader thread unblocks and marks
    /// the mux dead, cascading `Disconnected` to every receiver.
    pub fn shutdown(&self) {
        self.alive.store(false, Ordering::SeqCst);
        let _ = self.sock.shutdown(Shutdown::Both);
    }
}

#[cfg(test)]
mod tests {
    use super::super::proto::{encode_token, FrameKind};
    use super::*;
    use std::time::Duration;

    fn pair() -> ((Arc<Mux>, Receiver<Frame>), (Arc<Mux>, Receiver<Frame>)) {
        let (a, b) = UnixStream::pair().unwrap();
        let (atx, arx) = channel();
        let (btx, brx) = channel();
        ((Mux::start(a, atx).unwrap(), arx), (Mux::start(b, btx).unwrap(), brx))
    }

    #[test]
    fn frames_route_by_stream_id() {
        let ((gw, _gw_in), (rn, rn_in)) = pair();
        let (s1, rx1) = gw.open_stream();
        let (s2, rx2) = gw.open_stream();
        assert_ne!(s1, s2);
        // Unregistered ids land on the peer's inbound channel.
        gw.send(&Frame::new(FrameKind::Generate, s1, vec![1])).unwrap();
        gw.send(&Frame::new(FrameKind::Generate, s2, vec![2])).unwrap();
        let f1 = rn_in.recv_timeout(Duration::from_secs(5)).unwrap();
        let f2 = rn_in.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!((f1.stream, f1.payload.clone()), (s1, vec![1]));
        assert_eq!((f2.stream, f2.payload.clone()), (s2, vec![2]));
        // Replies tagged with the stream id come back on the right
        // receiver, interleaved or not.
        rn.send(&Frame::new(FrameKind::Token, s2, encode_token(7, "b"))).unwrap();
        rn.send(&Frame::new(FrameKind::Token, s1, encode_token(3, "a"))).unwrap();
        assert_eq!(rx1.recv_timeout(Duration::from_secs(5)).unwrap().stream, s1);
        assert_eq!(rx2.recv_timeout(Duration::from_secs(5)).unwrap().stream, s2);
    }

    #[test]
    fn peer_death_disconnects_every_receiver() {
        let ((gw, gw_in), (rn, _rn_in)) = pair();
        let (_s1, rx1) = gw.open_stream();
        let (_s2, rx2) = gw.open_stream();
        rn.shutdown();
        // Both per-stream receivers and the inbound channel observe the
        // death without any frame ever arriving.
        assert!(rx1.recv_timeout(Duration::from_secs(5)).is_err());
        assert!(rx2.recv_timeout(Duration::from_secs(5)).is_err());
        assert!(gw_in.recv_timeout(Duration::from_secs(5)).is_err());
        assert!(!gw.is_alive() || {
            // reader thread may still be between EOF and the flag store;
            // give it a beat
            std::thread::sleep(Duration::from_millis(200));
            !gw.is_alive()
        });
        assert!(gw.send(&Frame::control(FrameKind::Ping)).is_err());
    }

    #[test]
    fn concurrent_senders_do_not_interleave_frames() {
        let ((gw, _gw_in), (_rn, rn_in)) = pair();
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let gw = Arc::clone(&gw);
            handles.push(thread::spawn(move || {
                for i in 0..50u32 {
                    let payload = encode_token(i, &format!("t{t}"));
                    gw.send(&Frame::new(FrameKind::Token, 100 + t, payload)).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // All 200 frames decode cleanly — torn writes would poison the
        // wire and kill the reader early.
        for _ in 0..200 {
            let f = rn_in.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(f.kind, FrameKind::Token);
            assert!((100..104).contains(&f.stream));
        }
    }
}
