//! Versioned length-prefixed frame codec — the wire format of the
//! gateway <-> model-runner IPC layer.
//!
//! Every message on a runner connection is one [`Frame`]:
//!
//! ```text
//!   magic   u32 LE   0x50534652 ("PSFR")
//!   version u16 LE   protocol version (readers reject mismatches)
//!   kind    u8       FrameKind discriminant
//!   flags   u8       reserved, must be 0
//!   stream  u64 LE   multiplexer stream id (0 = connection control)
//!   len     u32 LE   payload length, <= MAX_PAYLOAD
//!   payload [u8; len]
//! ```
//!
//! Versioning rules: the header layout above is frozen forever; any
//! change to a payload encoding or the kind set bumps [`VERSION`].  A
//! reader that sees a different version fails the whole connection (the
//! supervisor then treats the runner as incompatible) — there is no
//! in-band negotiation, because gateway and runners ship in one binary
//! and can only disagree across an in-place upgrade, where tearing the
//! connection down is the correct behavior.
//!
//! Payloads are binary (little-endian, length-prefixed slices) rather
//! than JSON: token streams are hot-path traffic and the serving JSON
//! substrate (`serve::http`) is deliberately flat-objects-only.
//! Round-trip + corruption behavior is pinned by `tests/properties.rs`.

use std::io::{self, Read, Write};

use crate::infer::sampler::SamplePolicy;
use crate::infer::session::GenRequest;
use crate::serve::worker::RequestStats;

/// Frame magic: "PSFR" interpreted as a little-endian u32.
pub const MAGIC: u32 = 0x5053_4652;
/// Protocol version; bump on any payload/kind change.
/// v2: `Generate` payload gained a leading trace id (span stitching).
pub const VERSION: u16 = 2;
/// Hard payload ceiling: large enough for a long prefill's combined
/// activation matrix, small enough that a corrupt length field cannot
/// ask the reader to allocate gigabytes.
pub const MAX_PAYLOAD: u32 = 16 << 20;
/// Fixed header length in bytes.
pub const HEADER_LEN: usize = 20;

/// Message discriminants.  Stream 0 carries connection control
/// (`Hello`/`Ping`/`Pong`/`Shutdown`); every request opens its own
/// stream id for the remaining kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// runner -> gateway, once, on connect: identity + shard info.
    Hello = 0,
    /// gateway -> runner: serve one generation request on this stream.
    Generate = 1,
    /// runner -> gateway: one generated token.
    Token = 2,
    /// runner -> gateway: terminal accounting for the stream.
    Done = 3,
    /// runner -> gateway: terminal failure for the stream.
    Error = 4,
    /// gateway -> runner heartbeat probe.
    Ping = 5,
    /// runner -> gateway heartbeat answer.
    Pong = 6,
    /// gateway -> runner: report serve counters on this stream.
    MetricsReq = 7,
    /// runner -> gateway: counters as a JSON object string.
    MetricsReply = 8,
    /// gateway -> runner: drain and exit.
    Shutdown = 9,
    /// gateway -> runner: abandon the request on this stream.
    Cancel = 10,
    /// gateway -> runner: serve a head-sharded (tensor-parallel) request.
    TpGenerate = 11,
    /// runner -> gateway: this shard's partial attention output.
    TpPartial = 12,
    /// gateway -> runner: the world-summed attention output.
    TpCombined = 13,
}

impl FrameKind {
    pub fn from_u8(b: u8) -> Option<FrameKind> {
        use FrameKind::*;
        Some(match b {
            0 => Hello,
            1 => Generate,
            2 => Token,
            3 => Done,
            4 => Error,
            5 => Ping,
            6 => Pong,
            7 => MetricsReq,
            8 => MetricsReply,
            9 => Shutdown,
            10 => Cancel,
            11 => TpGenerate,
            12 => TpPartial,
            13 => TpCombined,
            _ => return None,
        })
    }
}

/// Decode failures, each naming what the reader saw.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ProtoError {
    /// Fewer bytes than one complete frame.
    Truncated,
    BadMagic(u32),
    VersionMismatch { got: u16, want: u16 },
    Oversize { len: u32, max: u32 },
    BadKind(u8),
    Malformed(&'static str),
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Truncated => write!(f, "truncated frame"),
            ProtoError::BadMagic(m) => write!(f, "bad frame magic {m:#010x}"),
            ProtoError::VersionMismatch { got, want } => {
                write!(f, "protocol version mismatch: peer speaks v{got}, this binary v{want}")
            }
            ProtoError::Oversize { len, max } => {
                write!(f, "frame payload {len} bytes exceeds the {max}-byte cap")
            }
            ProtoError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            ProtoError::Malformed(what) => write!(f, "malformed payload: {what}"),
        }
    }
}

impl std::error::Error for ProtoError {}

fn proto_io(e: ProtoError) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, e)
}

/// One wire message.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    /// Multiplexer stream id (0 = connection control).
    pub stream: u64,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn new(kind: FrameKind, stream: u64, payload: Vec<u8>) -> Frame {
        assert!(payload.len() as u64 <= MAX_PAYLOAD as u64, "payload over MAX_PAYLOAD");
        Frame { kind, stream, payload }
    }

    /// Control-plane frame with no payload.
    pub fn control(kind: FrameKind) -> Frame {
        Frame::new(kind, 0, Vec::new())
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(HEADER_LEN + self.payload.len());
        buf.extend_from_slice(&MAGIC.to_le_bytes());
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.push(self.kind as u8);
        buf.push(0); // flags, reserved
        buf.extend_from_slice(&self.stream.to_le_bytes());
        buf.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&self.payload);
        buf
    }

    /// Decode one frame from the front of `buf`, returning it and the
    /// number of bytes consumed.  Any strict prefix of a valid encoding
    /// yields [`ProtoError::Truncated`].
    pub fn decode(buf: &[u8]) -> Result<(Frame, usize), ProtoError> {
        if buf.len() < HEADER_LEN {
            return Err(ProtoError::Truncated);
        }
        let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
        if magic != MAGIC {
            return Err(ProtoError::BadMagic(magic));
        }
        let version = u16::from_le_bytes(buf[4..6].try_into().unwrap());
        if version != VERSION {
            return Err(ProtoError::VersionMismatch { got: version, want: VERSION });
        }
        let kind = FrameKind::from_u8(buf[6]).ok_or(ProtoError::BadKind(buf[6]))?;
        let stream = u64::from_le_bytes(buf[8..16].try_into().unwrap());
        let len = u32::from_le_bytes(buf[16..20].try_into().unwrap());
        if len > MAX_PAYLOAD {
            return Err(ProtoError::Oversize { len, max: MAX_PAYLOAD });
        }
        let total = HEADER_LEN + len as usize;
        if buf.len() < total {
            return Err(ProtoError::Truncated);
        }
        Ok((Frame { kind, stream, payload: buf[HEADER_LEN..total].to_vec() }, total))
    }

    /// Read one frame from a blocking reader.  `Ok(None)` is a clean EOF
    /// at a frame boundary; mid-frame EOF and malformed headers surface
    /// as `io::Error` (kind `UnexpectedEof` / `InvalidData`).
    pub fn read_from(r: &mut impl Read) -> io::Result<Option<Frame>> {
        let mut header = [0u8; HEADER_LEN];
        if !read_exact_or_eof(r, &mut header)? {
            return Ok(None);
        }
        let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
        if magic != MAGIC {
            return Err(proto_io(ProtoError::BadMagic(magic)));
        }
        let version = u16::from_le_bytes(header[4..6].try_into().unwrap());
        if version != VERSION {
            return Err(proto_io(ProtoError::VersionMismatch { got: version, want: VERSION }));
        }
        let kind = FrameKind::from_u8(header[6]).ok_or_else(|| proto_io(ProtoError::BadKind(header[6])))?;
        let stream = u64::from_le_bytes(header[8..16].try_into().unwrap());
        let len = u32::from_le_bytes(header[16..20].try_into().unwrap());
        if len > MAX_PAYLOAD {
            return Err(proto_io(ProtoError::Oversize { len, max: MAX_PAYLOAD }));
        }
        let mut payload = vec![0u8; len as usize];
        r.read_exact(&mut payload)?;
        Ok(Some(Frame { kind, stream, payload }))
    }

    pub fn write_to(&self, w: &mut impl Write) -> io::Result<()> {
        w.write_all(&self.encode())
    }
}

/// `read_exact` that distinguishes clean EOF before the first byte
/// (`Ok(false)`) from EOF mid-buffer (`Err(UnexpectedEof)`).
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> io::Result<bool> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(false);
                }
                return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "eof mid-frame"));
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

// ------------------------------------------------------- payload codecs

/// Little-endian payload writer.
#[derive(Default)]
pub struct WireWriter {
    buf: Vec<u8>,
}

impl WireWriter {
    pub fn new() -> WireWriter {
        WireWriter::default()
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f32(&mut self, v: f32) -> &mut Self {
        // Bit-exact: the determinism contract extends onto the wire.
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
        self
    }

    pub fn f64(&mut self, v: f64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
        self
    }

    pub fn bytes(&mut self, v: &[u8]) -> &mut Self {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
        self
    }

    pub fn str(&mut self, v: &str) -> &mut Self {
        self.bytes(v.as_bytes())
    }

    pub fn tokens(&mut self, v: &[u32]) -> &mut Self {
        self.u32(v.len() as u32);
        for &t in v {
            self.buf.extend_from_slice(&t.to_le_bytes());
        }
        self
    }

    pub fn f32s(&mut self, v: &[f32]) -> &mut Self {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
        self
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Little-endian payload reader; every getter fails cleanly on short or
/// oversized input instead of panicking.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> WireReader<'a> {
        WireReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        if self.buf.len() - self.pos < n {
            return Err(ProtoError::Malformed("payload too short"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32, ProtoError> {
        Ok(f32::from_bits(u32::from_le_bytes(self.take(4)?.try_into().unwrap())))
    }

    pub fn f64(&mut self) -> Result<f64, ProtoError> {
        Ok(f64::from_bits(u64::from_le_bytes(self.take(8)?.try_into().unwrap())))
    }

    /// Length-guarded slice count: a corrupt length cannot allocate more
    /// than the remaining payload holds.
    fn counted(&mut self, elem_bytes: usize) -> Result<usize, ProtoError> {
        let n = self.u32()? as usize;
        if n.checked_mul(elem_bytes).map_or(true, |b| b > self.buf.len() - self.pos) {
            return Err(ProtoError::Malformed("length field exceeds payload"));
        }
        Ok(n)
    }

    pub fn bytes(&mut self) -> Result<&'a [u8], ProtoError> {
        let n = self.counted(1)?;
        self.take(n)
    }

    pub fn str(&mut self) -> Result<String, ProtoError> {
        let b = self.bytes()?;
        String::from_utf8(b.to_vec()).map_err(|_| ProtoError::Malformed("invalid utf-8 string"))
    }

    pub fn tokens(&mut self) -> Result<Vec<u32>, ProtoError> {
        let n = self.counted(4)?;
        (0..n).map(|_| self.u32()).collect()
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>, ProtoError> {
        let n = self.counted(4)?;
        (0..n).map(|_| self.f32()).collect()
    }

    pub fn finish(self) -> Result<(), ProtoError> {
        if self.pos != self.buf.len() {
            return Err(ProtoError::Malformed("trailing bytes"));
        }
        Ok(())
    }
}

/// Runner identity announced on connect.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Hello {
    pub runner_id: u32,
    pub mech: String,
    /// Head range this runner computes: `[head_start, head_end)`.
    /// The full range marks a data-parallel replica.
    pub head_start: u32,
    pub head_end: u32,
}

pub fn encode_hello(h: &Hello) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.u32(h.runner_id).str(&h.mech).u32(h.head_start).u32(h.head_end);
    w.finish()
}

pub fn decode_hello(b: &[u8]) -> Result<Hello, ProtoError> {
    let mut r = WireReader::new(b);
    let h = Hello {
        runner_id: r.u32()?,
        mech: r.str()?,
        head_start: r.u32()?,
        head_end: r.u32()?,
    };
    r.finish()?;
    Ok(h)
}

fn policy_code(p: &SamplePolicy) -> (u8, f32, u64, f32) {
    match p {
        SamplePolicy::Greedy => (0, 0.0, 0, 0.0),
        SamplePolicy::Temperature(t) => (1, *t, 0, 0.0),
        SamplePolicy::TopK { k, temperature } => (2, *temperature, *k as u64, 0.0),
        SamplePolicy::TopP { p, temperature } => (3, *temperature, 0, *p),
    }
}

/// `trace_id` is the gateway-minted span-stitching id (0 = untraced);
/// it rides first in the payload so one request's spans share an id
/// across the gateway/runner process boundary.
pub fn encode_generate(req: &GenRequest, trace_id: u64) -> Vec<u8> {
    let (tag, temp, k, p) = policy_code(&req.policy);
    let mut w = WireWriter::new();
    w.u64(trace_id)
        .u64(req.seed)
        .u64(req.max_new_tokens as u64)
        .u8(tag)
        .f32(temp)
        .u64(k)
        .f32(p)
        .tokens(&req.prompt);
    w.finish()
}

pub fn decode_generate(b: &[u8]) -> Result<(GenRequest, u64), ProtoError> {
    let mut r = WireReader::new(b);
    let trace_id = r.u64()?;
    let seed = r.u64()?;
    let max_new = r.u64()? as usize;
    let tag = r.u8()?;
    let temp = r.f32()?;
    let k = r.u64()? as usize;
    let p = r.f32()?;
    let prompt = r.tokens()?;
    r.finish()?;
    let policy = match tag {
        0 => SamplePolicy::Greedy,
        1 => SamplePolicy::Temperature(temp),
        2 => SamplePolicy::TopK { k, temperature: temp },
        3 => SamplePolicy::TopP { p, temperature: temp },
        _ => return Err(ProtoError::Malformed("unknown sampling policy tag")),
    };
    Ok((GenRequest { prompt, max_new_tokens: max_new, policy, seed }, trace_id))
}

pub fn encode_token(token: u32, text: &str) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.u32(token).str(text);
    w.finish()
}

pub fn decode_token(b: &[u8]) -> Result<(u32, String), ProtoError> {
    let mut r = WireReader::new(b);
    let t = r.u32()?;
    let s = r.str()?;
    r.finish()?;
    Ok((t, s))
}

pub fn encode_done(s: &RequestStats) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.u64(s.id)
        .u64(s.prompt_len as u64)
        .u64(s.new_tokens as u64)
        .u8(s.cache_hit as u8)
        .f64(s.ttft_secs)
        .f64(s.prefill_secs)
        .f64(s.decode_secs)
        .f64(s.wall_secs)
        .tokens(&s.generated);
    w.finish()
}

pub fn decode_done(b: &[u8]) -> Result<RequestStats, ProtoError> {
    let mut r = WireReader::new(b);
    let s = RequestStats {
        id: r.u64()?,
        prompt_len: r.u64()? as usize,
        new_tokens: r.u64()? as usize,
        cache_hit: r.u8()? != 0,
        ttft_secs: r.f64()?,
        prefill_secs: r.f64()?,
        decode_secs: r.f64()?,
        wall_secs: r.f64()?,
        generated: r.tokens()?,
    };
    r.finish()?;
    Ok(s)
}

pub fn encode_error(retriable: bool, msg: &str) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.u8(retriable as u8).str(msg);
    w.finish()
}

pub fn decode_error(b: &[u8]) -> Result<(bool, String), ProtoError> {
    let mut r = WireReader::new(b);
    let retriable = r.u8()? != 0;
    let msg = r.str()?;
    r.finish()?;
    Ok((retriable, msg))
}

/// TP activation exchange: (layer index, row-major f32 data).  Used by
/// both `TpPartial` and `TpCombined`.
pub fn encode_tp_vec(layer: u32, data: &[f32]) -> Vec<u8> {
    let mut w = WireWriter::new();
    w.u32(layer).f32s(data);
    w.finish()
}

pub fn decode_tp_vec(b: &[u8]) -> Result<(u32, Vec<f32>), ProtoError> {
    let mut r = WireReader::new(b);
    let layer = r.u32()?;
    let data = r.f32s()?;
    r.finish()?;
    Ok((layer, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_frame() -> Frame {
        Frame::new(
            FrameKind::Generate,
            7,
            encode_generate(
                &GenRequest {
                    prompt: vec![0, 5, 9, 200],
                    max_new_tokens: 12,
                    policy: SamplePolicy::TopP { p: 0.9, temperature: 0.7 },
                    seed: 42,
                },
                0xdead_beef,
            ),
        )
    }

    #[test]
    fn frame_roundtrip() {
        let f = sample_frame();
        let bytes = f.encode();
        let (g, used) = Frame::decode(&bytes).unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(g, f);
        let (req, trace_id) = decode_generate(&g.payload).unwrap();
        assert_eq!(req.prompt, vec![0, 5, 9, 200]);
        assert_eq!(req.policy, SamplePolicy::TopP { p: 0.9, temperature: 0.7 });
        assert_eq!(trace_id, 0xdead_beef, "trace id survives the wire");
    }

    #[test]
    fn every_strict_prefix_is_truncated() {
        let bytes = sample_frame().encode();
        for cut in 0..bytes.len() {
            assert_eq!(
                Frame::decode(&bytes[..cut]).unwrap_err(),
                ProtoError::Truncated,
                "prefix of {cut} bytes"
            );
        }
    }

    #[test]
    fn corrupt_headers_are_rejected() {
        let good = sample_frame().encode();
        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xff;
        assert!(matches!(Frame::decode(&bad_magic), Err(ProtoError::BadMagic(_))));
        let mut bad_version = good.clone();
        bad_version[4] = 0xfe;
        assert!(matches!(
            Frame::decode(&bad_version),
            Err(ProtoError::VersionMismatch { got: 0xfe, want: VERSION })
        ));
        let mut bad_kind = good.clone();
        bad_kind[6] = 0x7f;
        assert!(matches!(Frame::decode(&bad_kind), Err(ProtoError::BadKind(0x7f))));
        let mut oversize = good;
        oversize[16..20].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(matches!(Frame::decode(&oversize), Err(ProtoError::Oversize { .. })));
    }

    #[test]
    fn read_from_stream_and_clean_eof() {
        let a = Frame::control(FrameKind::Ping);
        let b = Frame::new(FrameKind::Token, 3, encode_token(17, "q"));
        let mut wire = a.encode();
        wire.extend_from_slice(&b.encode());
        let mut cursor = std::io::Cursor::new(wire);
        assert_eq!(Frame::read_from(&mut cursor).unwrap().unwrap(), a);
        assert_eq!(Frame::read_from(&mut cursor).unwrap().unwrap(), b);
        assert!(Frame::read_from(&mut cursor).unwrap().is_none(), "clean EOF");
        // EOF mid-frame is an error, not a silent None.
        let mut partial = std::io::Cursor::new(a.encode()[..HEADER_LEN - 3].to_vec());
        assert!(Frame::read_from(&mut partial).is_err());
    }

    #[test]
    fn stats_and_error_payloads_roundtrip() {
        let s = RequestStats {
            id: 9,
            prompt_len: 4,
            new_tokens: 3,
            cache_hit: true,
            ttft_secs: 0.5,
            prefill_secs: 0.25,
            decode_secs: 0.125,
            wall_secs: 1.0,
            generated: vec![1, 2, 3],
        };
        let d = decode_done(&encode_done(&s)).unwrap();
        assert_eq!(d.id, 9);
        assert_eq!(d.generated, vec![1, 2, 3]);
        assert!(d.cache_hit);
        let (retriable, msg) = decode_error(&encode_error(true, "runner died")).unwrap();
        assert!(retriable);
        assert_eq!(msg, "runner died");
        let h = Hello { runner_id: 2, mech: "psk4_r4_b8_local".into(), head_start: 0, head_end: 4 };
        assert_eq!(decode_hello(&encode_hello(&h)).unwrap(), h);
        let (layer, data) = decode_tp_vec(&encode_tp_vec(5, &[1.0, -2.5])).unwrap();
        assert_eq!(layer, 5);
        assert_eq!(data, vec![1.0, -2.5]);
    }

    #[test]
    fn malformed_payloads_fail_cleanly() {
        assert!(decode_generate(&[1, 2, 3]).is_err());
        // A length field larger than the remaining payload must not
        // allocate or panic.
        let mut w = WireWriter::new();
        w.u32(u32::MAX);
        assert!(WireReader::new(&w.finish()).tokens().is_err());
        // Trailing garbage is rejected.
        let mut ok = encode_token(5, "x");
        ok.push(0);
        assert!(decode_token(&ok).is_err());
    }
}
