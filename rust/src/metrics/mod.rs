//! Metrics substrate: JSONL/CSV emission + an in-memory run recorder.
//!
//! No serde in this environment; JSON values are emitted by a tiny
//! hand-rolled encoder that covers the shapes we log (flat objects of
//! string/number/bool).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::obs::Hist;
use crate::util::stats::Ema;

/// A flat JSON-encodable record.
#[derive(Clone, Debug, Default)]
pub struct Record {
    fields: BTreeMap<String, Field>,
}

#[derive(Clone, Debug)]
pub enum Field {
    Str(String),
    F64(f64),
    I64(i64),
    Bool(bool),
}

impl Record {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn str(mut self, k: &str, v: impl Into<String>) -> Self {
        self.fields.insert(k.into(), Field::Str(v.into()));
        self
    }

    pub fn f64(mut self, k: &str, v: f64) -> Self {
        self.fields.insert(k.into(), Field::F64(v));
        self
    }

    pub fn i64(mut self, k: &str, v: i64) -> Self {
        self.fields.insert(k.into(), Field::I64(v));
        self
    }

    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.fields.insert(k.into(), Field::Bool(v));
        self
    }

    pub fn get_f64(&self, k: &str) -> Option<f64> {
        match self.fields.get(k)? {
            Field::F64(v) => Some(*v),
            Field::I64(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn to_json(&self) -> String {
        let mut s = String::from("{");
        for (i, (k, v)) in self.fields.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "{}:", json_escape(k));
            match v {
                Field::Str(x) => s.push_str(&json_escape(x)),
                Field::F64(x) => {
                    if x.is_finite() {
                        let _ = write!(s, "{x}");
                    } else {
                        s.push_str("null");
                    }
                }
                Field::I64(x) => {
                    let _ = write!(s, "{x}");
                }
                Field::Bool(x) => {
                    let _ = write!(s, "{x}");
                }
            }
        }
        s.push('}');
        s
    }
}

/// Escape a Prometheus label *value* (text exposition format: backslash,
/// double-quote, and newline must be escaped inside `label="..."`).
pub fn prom_escape_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Append-only JSONL writer.
pub struct JsonlWriter {
    w: BufWriter<File>,
    pub path: PathBuf,
}

impl JsonlWriter {
    pub fn create(path: &Path) -> anyhow::Result<Self> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let f = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(JsonlWriter { w: BufWriter::new(f), path: path.to_path_buf() })
    }

    pub fn write(&mut self, rec: &Record) -> anyhow::Result<()> {
        writeln!(self.w, "{}", rec.to_json())?;
        Ok(())
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

/// Training-run recorder: smoothed loss curve + periodic console lines +
/// JSONL persistence.
pub struct RunLogger {
    writer: Option<JsonlWriter>,
    ema: Ema,
    pub history: Vec<(u64, f64)>,
    echo_every: u64,
}

impl RunLogger {
    pub fn new(path: Option<&Path>, echo_every: u64) -> anyhow::Result<Self> {
        let writer = match path {
            Some(p) => Some(JsonlWriter::create(p)?),
            None => None,
        };
        Ok(RunLogger { writer, ema: Ema::new(0.05), history: Vec::new(), echo_every })
    }

    pub fn log_step(&mut self, step: u64, loss: f64, extra: Record) -> anyhow::Result<()> {
        let smooth = self.ema.push(loss);
        self.history.push((step, loss));
        if let Some(w) = &mut self.writer {
            let rec = extra.i64("step", step as i64).f64("loss", loss).f64("loss_ema", smooth);
            w.write(&rec)?;
        }
        if self.echo_every > 0 && step % self.echo_every == 0 {
            eprintln!("step {step:>6}  loss {loss:.4}  ema {smooth:.4}");
        }
        Ok(())
    }

    pub fn finish(&mut self) -> anyhow::Result<()> {
        if let Some(w) = &mut self.writer {
            w.flush()?;
        }
        Ok(())
    }

    pub fn final_ema(&self) -> Option<f64> {
        self.ema.get()
    }
}

/// Shared counters of the serving gateway (`serve::Gateway`): admission,
/// prompt-cache effectiveness, and latency distributions.
///
/// All fields are thread-safe — HTTP handler threads and decode workers
/// update them concurrently; [`ServeCounters::record`] freezes a snapshot
/// into the same JSONL [`Record`] shape every other subsystem logs, and
/// [`ServeCounters::prometheus_text`] renders the whole set as Prometheus
/// text exposition for `GET /metrics?format=prometheus`.
///
/// Latency distributions are fixed-bucket [`Hist`]s: memory is constant
/// no matter how long the server runs (this replaced an earlier sliding
/// sample window whose per-scrape clone+sort cost grew with the window).
pub struct ServeCounters {
    /// Requests accepted into the admission queue.
    pub admitted: AtomicU64,
    /// Requests bounced by admission control (HTTP 429).
    pub rejected: AtomicU64,
    /// Requests fully served (final token delivered).
    pub completed: AtomicU64,
    /// Prompt-prefix cache hits (prefill skipped).
    pub cache_hits: AtomicU64,
    /// Prompt-prefix cache misses (full prefill paid).
    pub cache_misses: AtomicU64,
    /// Current prompt-cache footprint in bytes (gauge).
    pub cache_bytes: AtomicU64,
    /// State-arena pages committed by the cache's arena (gauge).
    pub arena_pages: AtomicU64,
    /// Live (checked-out) arena slots (gauge).
    pub arena_slots_live: AtomicU64,
    /// Arena bytes committed — live + free-listed (gauge).
    pub arena_bytes_committed: AtomicU64,
    /// Total generated tokens across completed requests.
    pub tokens_generated: AtomicU64,
    /// Time-to-first-token, seconds.
    pub ttft: Hist,
    /// Per-decoded-token latency, seconds.
    pub token_latency: Hist,
    /// Admission-queue wait (submit to first worker touch), seconds.
    pub queue_wait: Hist,
    /// Gateway<->runner IPC round trip (heartbeat ping/pong), seconds.
    pub ipc_rtt: Hist,
    /// Prompt-cache lookup duration, seconds.
    pub cache_lookup: Hist,
}

impl Default for ServeCounters {
    fn default() -> Self {
        ServeCounters {
            admitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            cache_bytes: AtomicU64::new(0),
            arena_pages: AtomicU64::new(0),
            arena_slots_live: AtomicU64::new(0),
            arena_bytes_committed: AtomicU64::new(0),
            tokens_generated: AtomicU64::new(0),
            ttft: Hist::latency(),
            token_latency: Hist::latency(),
            queue_wait: Hist::latency(),
            ipc_rtt: Hist::latency(),
            cache_lookup: Hist::latency(),
        }
    }
}

impl ServeCounters {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one request's time-to-first-token.
    pub fn record_ttft(&self, secs: f64) {
        self.ttft.observe(secs);
    }

    /// Refresh the state-arena gauges from a page-ledger snapshot.
    pub fn record_arena(&self, s: &crate::mem::ArenaStats) {
        self.arena_pages.store(s.pages as u64, Ordering::Relaxed);
        self.arena_slots_live.store(s.slots_live as u64, Ordering::Relaxed);
        self.arena_bytes_committed.store(s.bytes_committed as u64, Ordering::Relaxed);
    }

    /// (p50, p99) TTFT in milliseconds.
    pub fn ttft_percentiles_ms(&self) -> (f64, f64) {
        (self.ttft.percentile(50.0) * 1e3, self.ttft.percentile(99.0) * 1e3)
    }

    /// Snapshot as a JSONL record (`kind = "serve_metrics"`).
    pub fn record(&self) -> Record {
        let (p50, p99) = self.ttft_percentiles_ms();
        Record::new()
            .str("kind", "serve_metrics")
            .i64("admitted", self.admitted.load(Ordering::Relaxed) as i64)
            .i64("rejected", self.rejected.load(Ordering::Relaxed) as i64)
            .i64("completed", self.completed.load(Ordering::Relaxed) as i64)
            .i64("cache_hits", self.cache_hits.load(Ordering::Relaxed) as i64)
            .i64("cache_misses", self.cache_misses.load(Ordering::Relaxed) as i64)
            .i64("cache_bytes", self.cache_bytes.load(Ordering::Relaxed) as i64)
            .i64("arena_pages", self.arena_pages.load(Ordering::Relaxed) as i64)
            .i64("arena_slots_live", self.arena_slots_live.load(Ordering::Relaxed) as i64)
            .i64(
                "arena_bytes_committed",
                self.arena_bytes_committed.load(Ordering::Relaxed) as i64,
            )
            .i64("tokens_generated", self.tokens_generated.load(Ordering::Relaxed) as i64)
            .f64("ttft_p50_ms", p50)
            .f64("ttft_p99_ms", p99)
    }

    /// Prometheus text exposition (content type
    /// `text/plain; version=0.0.4`): monotone counters as `_total`,
    /// `cache_bytes` as a gauge, and every latency [`Hist`].
    pub fn prometheus_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::with_capacity(4096);
        let counters: [(&str, &AtomicU64); 6] = [
            ("psf_requests_admitted_total", &self.admitted),
            ("psf_requests_rejected_total", &self.rejected),
            ("psf_requests_completed_total", &self.completed),
            ("psf_cache_hits_total", &self.cache_hits),
            ("psf_cache_misses_total", &self.cache_misses),
            ("psf_tokens_generated_total", &self.tokens_generated),
        ];
        for (name, v) in counters {
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {}", v.load(Ordering::Relaxed));
        }
        let gauges: [(&str, &AtomicU64); 4] = [
            ("psf_cache_bytes", &self.cache_bytes),
            ("psf_arena_pages", &self.arena_pages),
            ("psf_arena_slots_live", &self.arena_slots_live),
            ("psf_arena_bytes_committed", &self.arena_bytes_committed),
        ];
        for (name, v) in gauges {
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {}", v.load(Ordering::Relaxed));
        }
        self.ttft.prometheus_into("psf_ttft_seconds", "Time to first token", &mut out);
        self.token_latency.prometheus_into(
            "psf_token_latency_seconds",
            "Per-decoded-token latency",
            &mut out,
        );
        self.queue_wait.prometheus_into(
            "psf_queue_wait_seconds",
            "Admission queue wait before first worker touch",
            &mut out,
        );
        self.ipc_rtt.prometheus_into(
            "psf_ipc_rtt_seconds",
            "Gateway to runner IPC round trip",
            &mut out,
        );
        self.cache_lookup.prometheus_into(
            "psf_cache_lookup_seconds",
            "Prompt cache lookup duration",
            &mut out,
        );
        // Build identity + uptime (the text-exposition `_info` idiom:
        // constant 1, identity in the labels).
        let _ = writeln!(out, "# TYPE psf_build_info gauge");
        let _ = writeln!(
            out,
            "psf_build_info{{version=\"{}\",simd=\"{}\",quant=\"{}\"}} 1",
            prom_escape_label(env!("CARGO_PKG_VERSION")),
            prom_escape_label(crate::tensor::micro::backend_label()),
            prom_escape_label(crate::mem::quant::mode().label()),
        );
        let _ = writeln!(out, "# TYPE psf_uptime_seconds gauge");
        let _ = writeln!(out, "psf_uptime_seconds {:.3}", crate::obs::uptime_secs());
        // Span-ring health: per-thread occupancy and cumulative drops.
        // `dropped_total` never resets (unlike the per-flush counter the
        // trace file carries), so this stays a valid monotone counter.
        let rings = crate::obs::span::ring_stats();
        if !rings.is_empty() {
            let _ = writeln!(out, "# TYPE psf_span_ring_events gauge");
            for (tid, occ, _) in &rings {
                let _ = writeln!(out, "psf_span_ring_events{{tid=\"{tid}\"}} {occ}");
            }
            let _ = writeln!(out, "# TYPE psf_span_ring_dropped_total counter");
            for (tid, _, dropped) in &rings {
                let _ =
                    writeln!(out, "psf_span_ring_dropped_total{{tid=\"{tid}\"}} {dropped}");
            }
        }
        out
    }

    /// Register this counter set's gauges with the flight recorder, so
    /// incident dumps carry a time series of serve state.  Idempotent
    /// (recorder registration replaces by name).
    pub fn register_recorder_gauges(self: &Arc<Self>) {
        use crate::obs::recorder;
        let c = Arc::clone(self);
        recorder::register("cache_bytes", move || {
            c.cache_bytes.load(Ordering::Relaxed) as f64
        });
        let c = Arc::clone(self);
        recorder::register("arena_bytes_committed", move || {
            c.arena_bytes_committed.load(Ordering::Relaxed) as f64
        });
        let c = Arc::clone(self);
        recorder::register("tokens_generated", move || {
            c.tokens_generated.load(Ordering::Relaxed) as f64
        });
        let c = Arc::clone(self);
        recorder::register("requests_completed", move || {
            c.completed.load(Ordering::Relaxed) as f64
        });
        let c = Arc::clone(self);
        recorder::register("cache_hit_rate", move || {
            let hits = c.cache_hits.load(Ordering::Relaxed) as f64;
            let misses = c.cache_misses.load(Ordering::Relaxed) as f64;
            if hits + misses > 0.0 {
                hits / (hits + misses)
            } else {
                0.0
            }
        });
        recorder::register("inflight_requests", || {
            crate::obs::incident::inflight_count() as f64
        });
    }
}

/// Minimal CSV writer for bench tables.
pub struct CsvWriter {
    w: BufWriter<File>,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> anyhow::Result<Self> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(CsvWriter { w })
    }

    pub fn row(&mut self, cells: &[String]) -> anyhow::Result<()> {
        let quoted: Vec<String> = cells
            .iter()
            .map(|c| {
                if c.contains(',') || c.contains('"') {
                    format!("\"{}\"", c.replace('"', "\"\""))
                } else {
                    c.clone()
                }
            })
            .collect();
        writeln!(self.w, "{}", quoted.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_json_shape() {
        let r = Record::new().str("name", "x").f64("v", 1.5).i64("n", 3).bool("ok", true);
        assert_eq!(r.to_json(), r#"{"n":3,"name":"x","ok":true,"v":1.5}"#);
    }

    #[test]
    fn json_escaping() {
        assert_eq!(json_escape("a\"b\\c\nd"), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn prom_label_escaping() {
        assert_eq!(prom_escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(prom_escape_label("plain"), "plain");
    }

    #[test]
    fn nonfinite_becomes_null() {
        let r = Record::new().f64("v", f64::NAN);
        assert_eq!(r.to_json(), r#"{"v":null}"#);
    }

    #[test]
    fn jsonl_roundtrip() {
        let dir = std::env::temp_dir().join("psf_metrics_test");
        let path = dir.join("out.jsonl");
        let _ = fs::remove_file(&path);
        let mut w = JsonlWriter::create(&path).unwrap();
        w.write(&Record::new().i64("a", 1)).unwrap();
        w.write(&Record::new().i64("a", 2)).unwrap();
        w.flush().unwrap();
        let text = fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.lines().next().unwrap().contains("\"a\":1"));
    }

    #[test]
    fn run_logger_history() {
        let mut l = RunLogger::new(None, 0).unwrap();
        for s in 0..10 {
            l.log_step(s, 5.0 - s as f64 * 0.1, Record::new()).unwrap();
        }
        assert_eq!(l.history.len(), 10);
        assert!(l.final_ema().unwrap() < 5.0);
    }

    #[test]
    fn serve_counters_record_shape() {
        let c = ServeCounters::new();
        c.admitted.store(10, Ordering::Relaxed);
        c.rejected.store(2, Ordering::Relaxed);
        c.cache_hits.store(6, Ordering::Relaxed);
        c.cache_misses.store(4, Ordering::Relaxed);
        c.cache_bytes.store(4096, Ordering::Relaxed);
        for i in 0..100 {
            c.record_ttft(0.001 * (i + 1) as f64);
        }
        // Histogram percentiles are bucket-interpolated, not exact order
        // statistics: assert the right neighborhood, not sample values.
        let (p50, p99) = c.ttft_percentiles_ms();
        assert!(p50 >= 25.0 && p50 <= 50.0, "p50 {p50}");
        assert!(p99 > p50 && p99 <= 100.0, "p99 {p99}");
        let json = c.record().to_json();
        for needle in [
            "\"kind\":\"serve_metrics\"",
            "\"admitted\":10",
            "\"rejected\":2",
            "\"cache_hits\":6",
            "\"cache_bytes\":4096",
            "\"ttft_p50_ms\":",
            "\"ttft_p99_ms\":",
        ] {
            assert!(json.contains(needle), "{json} missing {needle}");
        }
    }

    #[test]
    fn serve_counters_empty_ttft_is_zero() {
        let c = ServeCounters::new();
        assert_eq!(c.ttft_percentiles_ms(), (0.0, 0.0));
    }

    #[test]
    fn serve_counters_ttft_memory_is_bounded() {
        let c = ServeCounters::new();
        let buckets = c.ttft.bucket_counts().len();
        for i in 0..50_000u64 {
            c.record_ttft((i % 400) as f64 * 1e-4);
        }
        // Fixed-bucket histogram: footprint never grows with samples.
        assert_eq!(c.ttft.bucket_counts().len(), buckets);
        assert_eq!(c.ttft.count(), 50_000);
        let (p50, p99) = c.ttft_percentiles_ms();
        assert!(p50 > 0.0 && p99 >= p50, "p50 {p50} p99 {p99}");
    }

    #[test]
    fn serve_counters_prometheus_text() {
        let c = ServeCounters::new();
        c.admitted.store(7, Ordering::Relaxed);
        c.cache_bytes.store(1024, Ordering::Relaxed);
        c.arena_pages.store(3, Ordering::Relaxed);
        c.arena_bytes_committed.store(196608, Ordering::Relaxed);
        c.record_ttft(0.03);
        c.queue_wait.observe(0.002);
        c.ipc_rtt.observe(0.0004);
        c.cache_lookup.observe(0.00002);
        c.token_latency.observe(0.008);
        let text = c.prometheus_text();
        for needle in [
            "# TYPE psf_requests_admitted_total counter",
            "psf_requests_admitted_total 7",
            "# TYPE psf_cache_bytes gauge",
            "psf_cache_bytes 1024",
            "# TYPE psf_arena_pages gauge",
            "psf_arena_pages 3",
            "psf_arena_bytes_committed 196608",
            "# TYPE psf_ttft_seconds histogram",
            "psf_ttft_seconds_count 1",
            "psf_queue_wait_seconds_count 1",
            "psf_ipc_rtt_seconds_count 1",
            "psf_cache_lookup_seconds_count 1",
            "psf_token_latency_seconds_count 1",
            "psf_ttft_seconds_bucket{le=\"+Inf\"} 1",
            "# TYPE psf_build_info gauge",
            "psf_build_info{version=\"",
            "# TYPE psf_uptime_seconds gauge",
            "psf_uptime_seconds ",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }

    #[test]
    fn csv_quotes_commas() {
        let dir = std::env::temp_dir().join("psf_metrics_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["x,y".into(), "z".into()]).unwrap();
        w.flush().unwrap();
        let text = fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"x,y\",z"));
    }
}
