//! Metrics substrate: JSONL/CSV emission + an in-memory run recorder.
//!
//! No serde in this environment; JSON values are emitted by a tiny
//! hand-rolled encoder that covers the shapes we log (flat objects of
//! string/number/bool).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use crate::util::stats::Ema;

/// A flat JSON-encodable record.
#[derive(Clone, Debug, Default)]
pub struct Record {
    fields: BTreeMap<String, Field>,
}

#[derive(Clone, Debug)]
pub enum Field {
    Str(String),
    F64(f64),
    I64(i64),
    Bool(bool),
}

impl Record {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn str(mut self, k: &str, v: impl Into<String>) -> Self {
        self.fields.insert(k.into(), Field::Str(v.into()));
        self
    }

    pub fn f64(mut self, k: &str, v: f64) -> Self {
        self.fields.insert(k.into(), Field::F64(v));
        self
    }

    pub fn i64(mut self, k: &str, v: i64) -> Self {
        self.fields.insert(k.into(), Field::I64(v));
        self
    }

    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.fields.insert(k.into(), Field::Bool(v));
        self
    }

    pub fn get_f64(&self, k: &str) -> Option<f64> {
        match self.fields.get(k)? {
            Field::F64(v) => Some(*v),
            Field::I64(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn to_json(&self) -> String {
        let mut s = String::from("{");
        for (i, (k, v)) in self.fields.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "{}:", json_escape(k));
            match v {
                Field::Str(x) => s.push_str(&json_escape(x)),
                Field::F64(x) => {
                    if x.is_finite() {
                        let _ = write!(s, "{x}");
                    } else {
                        s.push_str("null");
                    }
                }
                Field::I64(x) => {
                    let _ = write!(s, "{x}");
                }
                Field::Bool(x) => {
                    let _ = write!(s, "{x}");
                }
            }
        }
        s.push('}');
        s
    }
}

pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Append-only JSONL writer.
pub struct JsonlWriter {
    w: BufWriter<File>,
    pub path: PathBuf,
}

impl JsonlWriter {
    pub fn create(path: &Path) -> anyhow::Result<Self> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let f = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(JsonlWriter { w: BufWriter::new(f), path: path.to_path_buf() })
    }

    pub fn write(&mut self, rec: &Record) -> anyhow::Result<()> {
        writeln!(self.w, "{}", rec.to_json())?;
        Ok(())
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

/// Training-run recorder: smoothed loss curve + periodic console lines +
/// JSONL persistence.
pub struct RunLogger {
    writer: Option<JsonlWriter>,
    ema: Ema,
    pub history: Vec<(u64, f64)>,
    echo_every: u64,
}

impl RunLogger {
    pub fn new(path: Option<&Path>, echo_every: u64) -> anyhow::Result<Self> {
        let writer = match path {
            Some(p) => Some(JsonlWriter::create(p)?),
            None => None,
        };
        Ok(RunLogger { writer, ema: Ema::new(0.05), history: Vec::new(), echo_every })
    }

    pub fn log_step(&mut self, step: u64, loss: f64, extra: Record) -> anyhow::Result<()> {
        let smooth = self.ema.push(loss);
        self.history.push((step, loss));
        if let Some(w) = &mut self.writer {
            let rec = extra.i64("step", step as i64).f64("loss", loss).f64("loss_ema", smooth);
            w.write(&rec)?;
        }
        if self.echo_every > 0 && step % self.echo_every == 0 {
            eprintln!("step {step:>6}  loss {loss:.4}  ema {smooth:.4}");
        }
        Ok(())
    }

    pub fn finish(&mut self) -> anyhow::Result<()> {
        if let Some(w) = &mut self.writer {
            w.flush()?;
        }
        Ok(())
    }

    pub fn final_ema(&self) -> Option<f64> {
        self.ema.get()
    }
}

/// Minimal CSV writer for bench tables.
pub struct CsvWriter {
    w: BufWriter<File>,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> anyhow::Result<Self> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(CsvWriter { w })
    }

    pub fn row(&mut self, cells: &[String]) -> anyhow::Result<()> {
        let quoted: Vec<String> = cells
            .iter()
            .map(|c| {
                if c.contains(',') || c.contains('"') {
                    format!("\"{}\"", c.replace('"', "\"\""))
                } else {
                    c.clone()
                }
            })
            .collect();
        writeln!(self.w, "{}", quoted.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_json_shape() {
        let r = Record::new().str("name", "x").f64("v", 1.5).i64("n", 3).bool("ok", true);
        assert_eq!(r.to_json(), r#"{"n":3,"name":"x","ok":true,"v":1.5}"#);
    }

    #[test]
    fn json_escaping() {
        assert_eq!(json_escape("a\"b\\c\nd"), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn nonfinite_becomes_null() {
        let r = Record::new().f64("v", f64::NAN);
        assert_eq!(r.to_json(), r#"{"v":null}"#);
    }

    #[test]
    fn jsonl_roundtrip() {
        let dir = std::env::temp_dir().join("psf_metrics_test");
        let path = dir.join("out.jsonl");
        let _ = fs::remove_file(&path);
        let mut w = JsonlWriter::create(&path).unwrap();
        w.write(&Record::new().i64("a", 1)).unwrap();
        w.write(&Record::new().i64("a", 2)).unwrap();
        w.flush().unwrap();
        let text = fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.lines().next().unwrap().contains("\"a\":1"));
    }

    #[test]
    fn run_logger_history() {
        let mut l = RunLogger::new(None, 0).unwrap();
        for s in 0..10 {
            l.log_step(s, 5.0 - s as f64 * 0.1, Record::new()).unwrap();
        }
        assert_eq!(l.history.len(), 10);
        assert!(l.final_ema().unwrap() < 5.0);
    }

    #[test]
    fn csv_quotes_commas() {
        let dir = std::env::temp_dir().join("psf_metrics_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["x,y".into(), "z".into()]).unwrap();
        w.flush().unwrap();
        let text = fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"x,y\",z"));
    }
}
