//! Prompt-prefix state cache: the serving payoff of constant-size decode
//! states.
//!
//! A linear-attention decode state after prefilling a prompt is O(r²h) per
//! (layer, head) *regardless of prompt length* — so an entire system
//! prompt collapses into a snapshot a few KB big, and a repeated prompt
//! skips its prefill completely.  The softmax family can be cached too,
//! but its snapshots are O(n·h) KV tensors: the byte budget admits far
//! fewer of them, which is exactly the paper's complexity gap made
//! operational.
//!
//! Entries are stored *frozen* (`mem::freeze`): every f32 payload lives in
//! a slot of this cache's private [`StateArena`], converted to packed f16
//! halves when `PSF_QUANT` enables the cold tier.  Freezing on insert and
//! thawing on hit keeps active sessions in full f32 while cached prefixes
//! pay the narrow-storage price — and makes the byte ledger *exact*: entry
//! bytes are the arena slot sizes plus a fixed per-entry overhead
//! constant, not an estimate, and a debug assert reconciles the ledger
//! against the arena's live-byte counter on every insert.
//!
//! Keying is (mechanism label, exact prompt token sequence): the mechanism
//! label pins the state *shape* (same `HashMap` can serve several models),
//! and storing the full token sequence — not just its hash — makes
//! collisions impossible rather than improbable.  Eviction is LRU by a
//! byte budget; hit/miss/insert/eviction counters feed `GET /metrics` and
//! the `serve_metrics` JSONL record.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::infer::model::{LayerState, NativeLm};
use crate::infer::session::DecodeSession;
use crate::mem::{quant, ArenaStats, FrozenRow, FrozenState, QuantMode, StateArena};

/// Cache key: which model family the state belongs to + the exact prompt.
#[derive(Clone, Debug, Hash, PartialEq, Eq)]
pub struct CacheKey {
    pub mech: String,
    pub prompt: Vec<u32>,
}

/// Fixed per-entry bookkeeping charge: the `HashMap` entry, the key
/// struct, the `Arc`, and the `Entry` metadata.  A constant (rather than
/// a measured value) so the ledger stays exactly reproducible; 160 bytes
/// is deliberately on the generous side of what those structs occupy.
pub const ENTRY_OVERHEAD_BYTES: usize = 160;

/// The cached value: per-(layer, head) *frozen* decode states and the
/// frozen next-token logits of a session that prefilled the prompt and
/// has not decoded yet.  Cloning deep-copies through the arena.
#[derive(Clone)]
pub struct PrefixSnapshot {
    /// `frozen[layer][head]`.
    frozen: Vec<Vec<FrozenState>>,
    logits: FrozenRow,
}

impl PrefixSnapshot {
    /// Freeze the prompt-prefix state of a freshly prefilled session into
    /// `arena` slots, narrowing to f16 when `mode` enables the cold tier.
    /// Panics if the session has already decoded — a mid-generation state
    /// must never be served as a prompt prefix.
    pub fn freeze(session: &DecodeSession, mode: QuantMode, arena: &Arc<StateArena>) -> PrefixSnapshot {
        assert_eq!(session.new_tokens(), 0, "prefix snapshot of a session that already decoded");
        let frozen = session
            .states()
            .iter()
            .map(|l| l.heads.iter().map(|h| FrozenState::freeze(h, mode, arena)).collect())
            .collect();
        let logits = FrozenRow::freeze(session.last_logits(), mode, arena);
        PrefixSnapshot { frozen, logits }
    }

    /// Rebuild live decode states + logits, pairing each frozen head with
    /// the model's kernel for that (layer, head) (the f16 tier re-absorbs
    /// buffered tail rows through the kernel).  The caller hands the
    /// result straight to [`DecodeSession::from_prefix`].
    pub fn thaw(&self, model: &NativeLm) -> (Vec<LayerState>, Vec<f32>) {
        let states = self
            .frozen
            .iter()
            .zip(model.kernels())
            .map(|(layer, kernels)| LayerState {
                heads: layer.iter().zip(kernels).map(|(f, k)| f.thaw(k)).collect(),
            })
            .collect();
        (states, self.logits.thaw())
    }

    /// Exact arena footprint in bytes: the sum of the backing slot sizes.
    pub fn bytes(&self) -> usize {
        self.frozen.iter().flatten().map(FrozenState::arena_bytes).sum::<usize>()
            + self.logits.arena_bytes()
    }

    /// Whether this snapshot is stored in the f16 cold tier.
    pub fn is_f16(&self) -> bool {
        self.frozen.iter().flatten().any(FrozenState::is_f16)
    }
}

struct Entry {
    /// `Arc` so a hit is O(1) under the cache lock — the thaw a session
    /// needs happens on the caller's thread, outside the mutex.
    snap: Arc<PrefixSnapshot>,
    bytes: usize,
    /// Arena portion of `bytes` (the ledger ↔ arena reconciliation).
    arena_bytes: usize,
    last_used: u64,
}

#[derive(Default)]
struct Inner {
    map: HashMap<CacheKey, Entry>,
    bytes: usize,
    arena_bytes: usize,
    clock: u64,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
}

/// Point-in-time cache statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    pub entries: usize,
    pub bytes: usize,
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Thread-safe LRU prompt-prefix cache with a byte budget, backed by a
/// private paged [`StateArena`] holding every frozen payload.
pub struct PromptCache {
    inner: Mutex<Inner>,
    budget_bytes: usize,
    arena: Arc<StateArena>,
}

impl PromptCache {
    pub fn new(budget_bytes: usize) -> PromptCache {
        PromptCache { inner: Mutex::new(Inner::default()), budget_bytes, arena: StateArena::new() }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// The arena backing this cache's frozen entries (freeze into this;
    /// its stats drive `/healthz` and the admission pressure gauges).
    pub fn arena(&self) -> &Arc<StateArena> {
        &self.arena
    }

    /// Page-level arena counters (committed bytes, live slots, …).
    pub fn arena_stats(&self) -> ArenaStats {
        self.arena.stats()
    }

    /// Freeze a prefilled session into this cache's arena under the
    /// process-wide `PSF_QUANT` mode — the snapshot [`PromptCache::insert`]
    /// expects.
    pub fn freeze(&self, session: &DecodeSession) -> PrefixSnapshot {
        PrefixSnapshot::freeze(session, quant::mode(), &self.arena)
    }

    /// Look up a prompt prefix; a hit refreshes the LRU position and
    /// returns a shared handle (an `Arc` bump, not a copy — callers thaw
    /// the states they need outside the lock).  Every call counts as a
    /// hit or a miss.
    pub fn get(&self, key: &CacheKey) -> Option<Arc<PrefixSnapshot>> {
        let mut inner = self.lock();
        inner.clock += 1;
        let clock = inner.clock;
        match inner.map.get_mut(key) {
            Some(entry) => {
                entry.last_used = clock;
                let snap = Arc::clone(&entry.snap);
                inner.hits += 1;
                Some(snap)
            }
            None => {
                inner.misses += 1;
                None
            }
        }
    }

    /// Insert a prompt prefix (frozen via [`PromptCache::freeze`]),
    /// evicting least-recently-used entries until the byte budget holds.
    /// Admission is driven by the exact ledger — arena slot bytes + key
    /// bytes + [`ENTRY_OVERHEAD_BYTES`] — not an estimate.  A snapshot
    /// larger than the whole budget is dropped (releasing its slots)
    /// rather than wiping the cache for one uncacheable prompt.
    /// Inserting an existing key refreshes the entry without drifting the
    /// ledger.
    pub fn insert(&self, key: CacheKey, snap: PrefixSnapshot) {
        let arena_bytes = snap.bytes();
        let bytes = arena_bytes + key.prompt.len() * 4 + ENTRY_OVERHEAD_BYTES;
        if bytes > self.budget_bytes {
            return; // dropping `snap` releases its arena slots
        }
        {
            let mut inner = self.lock();
            inner.clock += 1;
            let clock = inner.clock;
            if let Some(old) = inner.map.remove(&key) {
                inner.bytes -= old.bytes;
                inner.arena_bytes -= old.arena_bytes;
            }
            while inner.bytes + bytes > self.budget_bytes {
                let Some(lru_key) = inner
                    .map
                    .iter()
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(k, _)| k.clone())
                else {
                    break;
                };
                let evicted = inner.map.remove(&lru_key).expect("lru key vanished");
                inner.bytes -= evicted.bytes;
                inner.arena_bytes -= evicted.arena_bytes;
                inner.evictions += 1;
            }
            inner
                .map
                .insert(key, Entry { snap: Arc::new(snap), bytes, arena_bytes, last_used: clock });
            inner.bytes += bytes;
            inner.arena_bytes += arena_bytes;
            inner.insertions += 1;
            // Ledger ↔ arena reconciliation: every live arena byte beyond
            // the ledger belongs to snapshots still held by callers
            // (outstanding `Arc`s, evicted-but-referenced entries), never
            // the other way around.
            debug_assert!(
                self.arena.stats().bytes_live >= inner.arena_bytes,
                "cache ledger ({}) exceeds arena live bytes ({})",
                inner.arena_bytes,
                self.arena.stats().bytes_live
            );
        }
        // Outside the map lock: cap the arena's committed (free-slot)
        // memory at the cache budget so eviction returns pages, not just
        // ledger headroom.
        self.arena.trim(self.budget_bytes);
    }

    pub fn stats(&self) -> CacheStats {
        let inner = self.lock();
        CacheStats {
            entries: inner.map.len(),
            bytes: inner.bytes,
            hits: inner.hits,
            misses: inner.misses,
            insertions: inner.insertions,
            evictions: inner.evictions,
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().expect("prompt cache lock poisoned")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::Mechanism;
    use crate::infer::model::LmConfig;
    use crate::infer::sampler::SamplePolicy;
    use crate::infer::session::GenRequest;
    use crate::infer::NativeLm;

    fn model(mech: Mechanism) -> NativeLm {
        let cfg = LmConfig { vocab: 64, d_model: 32, layers: 2, heads: 2, ff_mult: 2, seed: 5 };
        NativeLm::new(cfg, mech)
    }

    fn session(model: &NativeLm, prompt: &[u32]) -> DecodeSession {
        let req = GenRequest {
            prompt: prompt.to_vec(),
            max_new_tokens: 0,
            policy: SamplePolicy::Greedy,
            seed: 0,
        };
        DecodeSession::new(model, 0, req)
    }

    fn key(model: &NativeLm, prompt: &[u32]) -> CacheKey {
        CacheKey { mech: model.mech.label(), prompt: prompt.to_vec() }
    }

    #[test]
    fn hit_thaws_to_equal_state_and_counts() {
        let m = model(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true });
        let cache = PromptCache::new(10 << 20);
        let prompt = vec![0u32, 3, 7, 9];
        assert!(cache.get(&key(&m, &prompt)).is_none());
        let s = session(&m, &prompt);
        cache.insert(key(&m, &prompt), cache.freeze(&s));
        let got = cache.get(&key(&m, &prompt)).expect("hit");
        let (_, logits) = got.thaw(&m);
        // Default mode is off → the frozen round trip is bitwise.
        assert_eq!(logits, s.last_logits());
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.entries), (1, 1, 1));
        assert!(st.bytes > 0);
        assert!((st.hit_rate() - 0.5).abs() < 1e-12);
        // The arena holds exactly the one entry's payload (live bytes
        // match the ledger's arena portion).
        assert!(cache.arena_stats().bytes_live > 0);
    }

    #[test]
    fn distinct_prompts_and_mechanisms_do_not_collide() {
        let a = model(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true });
        let b = model(Mechanism::Softmax);
        let cache = PromptCache::new(10 << 20);
        cache.insert(key(&a, &[0, 1]), cache.freeze(&session(&a, &[0, 1])));
        assert!(cache.get(&key(&a, &[0, 1, 2])).is_none());
        assert!(cache.get(&key(&b, &[0, 1])).is_none());
        assert!(cache.get(&key(&a, &[0, 1])).is_some());
    }

    #[test]
    fn linear_snapshot_is_constant_size_while_kv_grows() {
        // The constant-size-cache argument, measured: quadrupling the
        // prompt leaves the polysketch snapshot's footprint unchanged
        // (modulo the in-progress block buffer at block-aligned lengths)
        // but blows up the softmax KV snapshot.
        let lin = model(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: false });
        let kv = model(Mechanism::Softmax);
        let cache = PromptCache::new(10 << 20);
        let short: Vec<u32> = (0..64u32).map(|i| i % 60).collect();
        let long: Vec<u32> = (0..256u32).map(|i| i % 60).collect();
        assert_eq!(
            cache.freeze(&session(&lin, &short)).bytes(),
            cache.freeze(&session(&lin, &long)).bytes()
        );
        assert!(
            cache.freeze(&session(&kv, &long)).bytes()
                > 2 * cache.freeze(&session(&kv, &short)).bytes()
        );
    }

    #[test]
    fn lru_eviction_respects_byte_budget_and_releases_arena_slots() {
        let m = model(Mechanism::Softmax);
        let prompts: Vec<Vec<u32>> =
            (0..4).map(|s| (0..32u32).map(|i| (i + s) % 60).collect()).collect();
        // All four prompts have identical shape, so one probe fixes the
        // exact per-entry charge.
        let probe = PromptCache::new(10 << 20);
        let one = probe.freeze(&session(&m, &prompts[0])).bytes()
            + prompts[0].len() * 4
            + ENTRY_OVERHEAD_BYTES;
        // Budget for two entries.
        let cache = PromptCache::new(2 * one + one / 2);
        for p in &prompts[..3] {
            cache.insert(key(&m, p), cache.freeze(&session(&m, p)));
        }
        let s = cache.stats();
        assert_eq!(s.entries, 2, "{s:?}");
        assert_eq!(s.evictions, 1);
        assert!(s.bytes <= cache.budget_bytes());
        // Eviction returned the evicted entry's slots to the free list;
        // trim then keeps committed memory at or under the budget scale.
        // 2 layers × 2 heads + 1 logits row = 5 slots per entry.
        let astats = cache.arena_stats();
        assert_eq!(astats.slots_live, 5 * cache.stats().entries);
        // prompts[0] was LRU, so it is the one gone.
        assert!(cache.get(&key(&m, &prompts[0])).is_none());
        assert!(cache.get(&key(&m, &prompts[1])).is_some());
        assert!(cache.get(&key(&m, &prompts[2])).is_some());
        // Touch prompts[1]; inserting prompts[3] must now evict prompts[2].
        assert!(cache.get(&key(&m, &prompts[1])).is_some());
        cache.insert(key(&m, &prompts[3]), cache.freeze(&session(&m, &prompts[3])));
        assert!(cache.get(&key(&m, &prompts[1])).is_some());
        assert!(cache.get(&key(&m, &prompts[2])).is_none());
        assert!(cache.get(&key(&m, &prompts[3])).is_some());
    }

    #[test]
    fn reinsertion_does_not_drift_the_ledger() {
        let m = model(Mechanism::Softmax);
        let prompt: Vec<u32> = (0..16u32).collect();
        let cache = PromptCache::new(10 << 20);
        cache.insert(key(&m, &prompt), cache.freeze(&session(&m, &prompt)));
        let once = cache.stats().bytes;
        for _ in 0..5 {
            cache.insert(key(&m, &prompt), cache.freeze(&session(&m, &prompt)));
        }
        let st = cache.stats();
        assert_eq!(st.bytes, once, "re-inserting the same key drifted the ledger");
        assert_eq!(st.entries, 1);
        // The replaced snapshots' slots went back to the free list: live
        // slots stay at one entry's worth (4 head states + 1 logits row).
        assert_eq!(cache.arena_stats().slots_live, 5);
    }

    #[test]
    fn oversized_snapshot_is_not_inserted() {
        let m = model(Mechanism::Softmax);
        let prompt: Vec<u32> = (0..64u32).collect();
        let cache = PromptCache::new(16); // tiny budget
        cache.insert(key(&m, &prompt), cache.freeze(&session(&m, &prompt)));
        assert_eq!(cache.stats().entries, 0);
        assert_eq!(cache.stats().insertions, 0);
        // The rejected snapshot's slots were released, not leaked.
        assert_eq!(cache.arena_stats().slots_live, 0);
    }
}
