//! Prompt-prefix state cache: the serving payoff of constant-size decode
//! states.
//!
//! A linear-attention decode state after prefilling a prompt is O(r²h) per
//! (layer, head) *regardless of prompt length* — so an entire system
//! prompt collapses into a snapshot a few KB big, and a repeated prompt
//! skips its prefill completely.  The softmax family can be cached too,
//! but its snapshots are O(n·h) KV tensors: the byte budget admits far
//! fewer of them, which is exactly the paper's complexity gap made
//! operational (`KernelState::memory_floats` in `attn::kernel` is the per-engine
//! accounting).
//!
//! Keying is (mechanism label, exact prompt token sequence): the mechanism
//! label pins the state *shape* (same `HashMap` can serve several models),
//! and storing the full token sequence — not just its hash — makes
//! collisions impossible rather than improbable.  Eviction is LRU by a
//! byte budget; hit/miss/insert/eviction counters feed `GET /metrics` and
//! the `serve_metrics` JSONL record.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::infer::model::{LayerState, NativeLm};
use crate::infer::session::{DecodeSession, SessionSnapshot};

/// Cache key: which model family the state belongs to + the exact prompt.
#[derive(Clone, Debug, Hash, PartialEq, Eq)]
pub struct CacheKey {
    pub mech: String,
    pub prompt: Vec<u32>,
}

/// The cached value: per-layer decode states and the next-token logits of
/// a session that prefilled the prompt and has not decoded yet.
#[derive(Clone)]
pub struct PrefixSnapshot {
    pub states: Vec<LayerState>,
    pub last_logits: Vec<f32>,
}

impl PrefixSnapshot {
    /// Capture the prompt-prefix state of a freshly prefilled session.
    /// Panics if the session has already decoded — a mid-generation state
    /// must never be served as a prompt prefix.
    pub fn of(session: &DecodeSession) -> PrefixSnapshot {
        let snap: SessionSnapshot = session.snapshot();
        assert_eq!(snap.new_tokens(), 0, "prefix snapshot of a session that already decoded");
        PrefixSnapshot { states: snap.states, last_logits: snap.last_logits }
    }

    /// Approximate heap footprint in bytes (f32 payloads dominate).  The
    /// sketch/feature projections are *not* counted: they live behind
    /// `Arc` and are shared with the model, not duplicated per entry.
    pub fn bytes(&self) -> usize {
        (NativeLm::state_memory_floats(&self.states) + self.last_logits.len()) * 4
    }
}

struct Entry {
    /// `Arc` so a hit is O(1) under the cache lock — the deep copy a
    /// session needs happens on the caller's thread, outside the mutex.
    snap: Arc<PrefixSnapshot>,
    bytes: usize,
    last_used: u64,
}

#[derive(Default)]
struct Inner {
    map: HashMap<CacheKey, Entry>,
    bytes: usize,
    clock: u64,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
}

/// Point-in-time cache statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    pub entries: usize,
    pub bytes: usize,
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Thread-safe LRU prompt-prefix cache with a byte budget.
pub struct PromptCache {
    inner: Mutex<Inner>,
    budget_bytes: usize,
}

impl PromptCache {
    pub fn new(budget_bytes: usize) -> PromptCache {
        PromptCache { inner: Mutex::new(Inner::default()), budget_bytes }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Look up a prompt prefix; a hit refreshes the LRU position and
    /// returns a shared handle (an `Arc` bump, not a copy — callers clone
    /// the states they need outside the lock).  Every call counts as a
    /// hit or a miss.
    pub fn get(&self, key: &CacheKey) -> Option<Arc<PrefixSnapshot>> {
        let mut inner = self.lock();
        inner.clock += 1;
        let clock = inner.clock;
        match inner.map.get_mut(key) {
            Some(entry) => {
                entry.last_used = clock;
                let snap = Arc::clone(&entry.snap);
                inner.hits += 1;
                Some(snap)
            }
            None => {
                inner.misses += 1;
                None
            }
        }
    }

    /// Insert a prompt prefix, evicting least-recently-used entries until
    /// the byte budget holds.  A snapshot larger than the whole budget is
    /// dropped rather than wiping the cache for one uncacheable prompt.
    /// Inserting an existing key refreshes the entry.
    pub fn insert(&self, key: CacheKey, snap: PrefixSnapshot) {
        let bytes = snap.bytes() + key.prompt.len() * 4;
        if bytes > self.budget_bytes {
            return;
        }
        let mut inner = self.lock();
        inner.clock += 1;
        let clock = inner.clock;
        if let Some(old) = inner.map.remove(&key) {
            inner.bytes -= old.bytes;
        }
        while inner.bytes + bytes > self.budget_bytes {
            let Some(lru_key) = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            else {
                break;
            };
            let evicted = inner.map.remove(&lru_key).expect("lru key vanished");
            inner.bytes -= evicted.bytes;
            inner.evictions += 1;
        }
        inner.map.insert(key, Entry { snap: Arc::new(snap), bytes, last_used: clock });
        inner.bytes += bytes;
        inner.insertions += 1;
    }

    pub fn stats(&self) -> CacheStats {
        let inner = self.lock();
        CacheStats {
            entries: inner.map.len(),
            bytes: inner.bytes,
            hits: inner.hits,
            misses: inner.misses,
            insertions: inner.insertions,
            evictions: inner.evictions,
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().expect("prompt cache lock poisoned")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::Mechanism;
    use crate::infer::model::LmConfig;
    use crate::infer::sampler::SamplePolicy;
    use crate::infer::session::GenRequest;
    use crate::infer::NativeLm;

    fn model(mech: Mechanism) -> NativeLm {
        let cfg = LmConfig { vocab: 64, d_model: 32, layers: 2, heads: 2, ff_mult: 2, seed: 5 };
        NativeLm::new(cfg, mech)
    }

    fn prefix(model: &NativeLm, prompt: &[u32]) -> PrefixSnapshot {
        let req = GenRequest {
            prompt: prompt.to_vec(),
            max_new_tokens: 0,
            policy: SamplePolicy::Greedy,
            seed: 0,
        };
        PrefixSnapshot::of(&DecodeSession::new(model, 0, req))
    }

    fn key(model: &NativeLm, prompt: &[u32]) -> CacheKey {
        CacheKey { mech: model.mech.label(), prompt: prompt.to_vec() }
    }

    #[test]
    fn hit_returns_equal_snapshot_and_counts() {
        let m = model(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true });
        let cache = PromptCache::new(10 << 20);
        let prompt = vec![0u32, 3, 7, 9];
        assert!(cache.get(&key(&m, &prompt)).is_none());
        let snap = prefix(&m, &prompt);
        cache.insert(key(&m, &prompt), snap.clone());
        let got = cache.get(&key(&m, &prompt)).expect("hit");
        assert_eq!(got.last_logits, snap.last_logits);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!(s.bytes > 0);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn distinct_prompts_and_mechanisms_do_not_collide() {
        let a = model(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true });
        let b = model(Mechanism::Softmax);
        let cache = PromptCache::new(10 << 20);
        cache.insert(key(&a, &[0, 1]), prefix(&a, &[0, 1]));
        assert!(cache.get(&key(&a, &[0, 1, 2])).is_none());
        assert!(cache.get(&key(&b, &[0, 1])).is_none());
        assert!(cache.get(&key(&a, &[0, 1])).is_some());
    }

    #[test]
    fn linear_snapshot_is_constant_size_while_kv_grows() {
        // The constant-size-cache argument, measured: doubling the prompt
        // leaves the polysketch snapshot's footprint unchanged (modulo the
        // in-progress block buffer at block-aligned lengths) but doubles
        // the softmax KV snapshot.
        let lin = model(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: false });
        let kv = model(Mechanism::Softmax);
        let short: Vec<u32> = (0..64u32).map(|i| i % 60).collect();
        let long: Vec<u32> = (0..256u32).map(|i| i % 60).collect();
        assert_eq!(prefix(&lin, &short).bytes(), prefix(&lin, &long).bytes());
        assert!(prefix(&kv, &long).bytes() > 2 * prefix(&kv, &short).bytes());
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        let m = model(Mechanism::Softmax);
        let prompts: Vec<Vec<u32>> =
            (0..4).map(|s| (0..32u32).map(|i| (i + s) % 60).collect()).collect();
        let one = prefix(&m, &prompts[0]).bytes() + prompts[0].len() * 4;
        // Budget for two entries (all four prompts have identical shape).
        let cache = PromptCache::new(2 * one + one / 2);
        for p in &prompts[..3] {
            cache.insert(key(&m, p), prefix(&m, p));
        }
        let s = cache.stats();
        assert_eq!(s.entries, 2, "{s:?}");
        assert_eq!(s.evictions, 1);
        assert!(s.bytes <= cache.budget_bytes());
        // prompts[0] was LRU, so it is the one gone.
        assert!(cache.get(&key(&m, &prompts[0])).is_none());
        assert!(cache.get(&key(&m, &prompts[1])).is_some());
        assert!(cache.get(&key(&m, &prompts[2])).is_some());
        // Touch prompts[1]; inserting prompts[3] must now evict prompts[2].
        assert!(cache.get(&key(&m, &prompts[1])).is_some());
        cache.insert(key(&m, &prompts[3]), prefix(&m, &prompts[3]));
        assert!(cache.get(&key(&m, &prompts[1])).is_some());
        assert!(cache.get(&key(&m, &prompts[2])).is_none());
        assert!(cache.get(&key(&m, &prompts[3])).is_some());
    }

    #[test]
    fn oversized_snapshot_is_not_inserted() {
        let m = model(Mechanism::Softmax);
        let prompt: Vec<u32> = (0..64u32).collect();
        let cache = PromptCache::new(16); // tiny budget
        cache.insert(key(&m, &prompt), prefix(&m, &prompt));
        assert_eq!(cache.stats().entries, 0);
        assert_eq!(cache.stats().insertions, 0);
    }
}
