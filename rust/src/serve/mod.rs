//! Serving gateway: concurrent front-end over the linear-time decode path.
//!
//! `infer/` made one request cheap (O(1)/token recurrent states); this
//! module makes *traffic* cheap.  Zero new dependencies — std sockets,
//! std threads — in four parts:
//!
//! * [`http`] — hand-rolled HTTP/1.1: request parsing, chunked per-token
//!   streaming, a flat JSON body parser, a threaded accept loop;
//! * [`cache`] — the prompt-prefix state cache.  The paper's recurrent
//!   view makes a prefilled prompt a *constant-size* snapshot (O(r²h) per
//!   layer/head) for the linear mechanisms, so repeated system prompts
//!   skip prefill entirely; the softmax family can be cached too but pays
//!   O(n·h) per entry — the complexity gap (Keles et al.) as a cache
//!   budget line-item;
//! * [`worker`] — decode workers over one shared `Arc<NativeLm>`,
//!   interleaving single-token step slices across sessions (continuous
//!   batching, multi-threaded) with graceful drain;
//! * [`gateway`] — the request lifecycle: admission control (bounded
//!   queue, 429 on overflow), cache, workers, per-request TTFT /
//!   tokens-per-sec accounting, `POST /v1/generate` + `GET /healthz` +
//!   `GET /metrics`.
//!
//! Determinism contract, inherited from `infer` and preserved across
//! threads: a (seed, prompt, policy) triple yields the same token stream
//! whether it was served cold, from the cache, by one worker or by eight
//! — `tests/integration_serve.rs` pins this for every mechanism.
//! `benches/serve_load.rs` measures the payoff (cache-hit TTFT, flat p99).

pub mod cache;
pub mod gateway;
pub mod http;
pub mod worker;

pub use cache::{CacheKey, CacheStats, PrefixSnapshot, PromptCache};
pub use gateway::{
    collect_stream, done_chunk, parse_generate_body, token_chunk, Gateway, GatewayConfig,
    GenDefaults, Rejected,
};
pub use http::{HttpRequest, HttpServer, Responder};
pub use worker::{RequestStats, ServeJob, TokenEvent, WorkerConfig, WorkerPool};
