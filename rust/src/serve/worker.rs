//! Decode worker pool: continuous batching across threads.
//!
//! One immutable `Arc<NativeLm>` is shared by every worker (the model is
//! pure data — no interior mutability — so `Sync` comes for free); each
//! request owns its private `DecodeSession`, which is what makes
//! cross-thread interleaving safe *and* deterministic: a session's token
//! stream depends only on (seed, prompt, policy), never on which worker
//! stepped it or when (the same contract `infer::scheduler` enforces on
//! one thread).
//!
//! Scheduling discipline: a shared admission queue plus a shared runnable
//! queue.  A worker prefers admitting (prefill or prompt-cache restore)
//! while the resident count is under `max_resident`, otherwise it pops a
//! runnable session, steps it `slice_tokens` tokens, and requeues it — so
//! sessions migrate freely between workers and short requests are not
//! stuck behind long ones (continuous batching, multi-threaded).
//! Shutdown is a graceful drain: no new admissions are accepted, but
//! everything already admitted or queued runs to completion before the
//! workers exit.
//!
//! Two parallelism layers compose here: these decode workers provide
//! *session-level* parallelism (each worker drives a different session's
//! slice), while the deterministic compute backend (`exec::pool`, sized
//! by `--threads`/`PSF_THREADS`) provides *intra-op* parallelism under
//! each prefill a worker performs during admission.  Decode steps are
//! 1-row ops that stay below the backend's dispatch thresholds, so slice
//! stepping never contends for the pool — and since the backend is
//! bitwise thread-count invariant, the byte-identity contracts below are
//! unaffected by either layer.

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::infer::model::NativeLm;
use crate::infer::session::{decode_text, DecodeSession, GenRequest};
use crate::metrics::ServeCounters;
use crate::obs;
use crate::serve::cache::{CacheKey, PromptCache};

/// Worker-pool knobs.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// Decode worker threads.
    pub workers: usize,
    /// Tokens a worker generates per session grab before requeueing it —
    /// the fairness/throughput dial (1 = strict round-robin).
    pub slice_tokens: usize,
    /// Maximum sessions resident (admitted, unfinished) across the pool.
    pub max_resident: usize,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig { workers: 2, slice_tokens: 4, max_resident: 8 }
    }
}

/// What streams back to the request's submitter.
#[derive(Clone, Debug)]
pub enum TokenEvent {
    /// One generated token, with its decoded text (byte-level vocab).
    Token { token: u32, text: String },
    /// Terminal event: the request's accounting.
    Done(RequestStats),
}

/// Per-request accounting, reported on completion.
#[derive(Clone, Debug)]
pub struct RequestStats {
    pub id: u64,
    pub prompt_len: usize,
    pub new_tokens: usize,
    /// Prompt prefix restored from the cache (prefill skipped)?
    pub cache_hit: bool,
    /// Queue-entry to first-token wall time.
    pub ttft_secs: f64,
    /// Prefill wall time (0 on a cache hit).
    pub prefill_secs: f64,
    /// Accumulated decode wall time.
    pub decode_secs: f64,
    /// Queue-entry to completion wall time.
    pub wall_secs: f64,
    /// The generated suffix (prompt excluded).
    pub generated: Vec<u32>,
}

impl RequestStats {
    pub fn decode_tokens_per_sec(&self) -> f64 {
        if self.decode_secs > 0.0 {
            self.new_tokens as f64 / self.decode_secs
        } else {
            0.0
        }
    }
}

/// One admitted-but-not-yet-prefilled request.
pub struct ServeJob {
    pub id: u64,
    pub req: GenRequest,
    pub events: Sender<TokenEvent>,
    pub queued: Instant,
    /// Request trace id for span stitching across threads and processes
    /// (0 = untraced).
    pub trace: u64,
}

/// A session resident in the pool, between step slices.
struct Running {
    session: DecodeSession,
    events: Sender<TokenEvent>,
    queued: Instant,
    ttft_secs: Option<f64>,
    cache_hit: bool,
    /// Peer hung up (send failed) — finish silently, skip accounting.
    cancelled: bool,
    trace: u64,
}

#[derive(Default)]
struct Queues {
    admit: VecDeque<ServeJob>,
    run: VecDeque<Running>,
    /// Sessions admitted and not yet retired (includes sessions currently
    /// held by a worker, which are in neither queue).
    resident: usize,
    draining: bool,
}

struct Shared {
    model: Arc<NativeLm>,
    cache: Arc<PromptCache>,
    counters: Arc<ServeCounters>,
    cfg: WorkerConfig,
    queues: Mutex<Queues>,
    cvar: Condvar,
}

/// The pool: spawn on construction, `try_submit` to feed it, `drain` to
/// finish outstanding work and join the threads.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl WorkerPool {
    pub fn new(
        model: Arc<NativeLm>,
        cache: Arc<PromptCache>,
        counters: Arc<ServeCounters>,
        cfg: WorkerConfig,
    ) -> WorkerPool {
        let shared = Arc::new(Shared {
            model,
            cache,
            counters,
            cfg: WorkerConfig {
                workers: cfg.workers.max(1),
                slice_tokens: cfg.slice_tokens.max(1),
                max_resident: cfg.max_resident.max(1),
            },
            queues: Mutex::new(Queues::default()),
            cvar: Condvar::new(),
        });
        let handles = (0..shared.cfg.workers)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        WorkerPool { shared, handles: Mutex::new(handles) }
    }

    /// Admission control: enqueue unless the admission queue is at
    /// `queue_cap` or the pool is draining — both hand the job back so the
    /// caller can answer 429/503.  The depth check and the enqueue are one
    /// critical section, so the cap holds under concurrent submitters.
    pub fn try_submit(&self, job: ServeJob, queue_cap: usize) -> Result<(), ServeJob> {
        let mut q = self.lock();
        if q.draining || q.admit.len() >= queue_cap.max(1) {
            return Err(job);
        }
        q.admit.push_back(job);
        drop(q);
        self.shared.cvar.notify_one();
        Ok(())
    }

    /// Admission-queue depth right now.
    pub fn queued(&self) -> usize {
        self.lock().admit.len()
    }

    /// Sessions admitted and not yet retired.
    pub fn resident(&self) -> usize {
        self.lock().resident
    }

    /// Graceful drain: stop admitting, run everything already accepted to
    /// completion, join the workers.  Idempotent-ish: callable once.
    pub fn drain(&self) {
        {
            let mut q = self.lock();
            q.draining = true;
        }
        self.shared.cvar.notify_all();
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.handles.lock().expect("handles lock poisoned"));
        for h in handles {
            let _ = h.join();
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Queues> {
        self.shared.queues.lock().expect("worker queues lock poisoned")
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        enum Work {
            Admit(ServeJob),
            Step(Running),
            Exit,
        }
        let work = {
            let mut q = shared.queues.lock().expect("worker queues lock poisoned");
            loop {
                // Prefer admission while under the residency cap: keeps the
                // batch full, which is what continuous batching is for.
                if q.resident < shared.cfg.max_resident {
                    if let Some(job) = q.admit.pop_front() {
                        q.resident += 1;
                        break Work::Admit(job);
                    }
                }
                if let Some(r) = q.run.pop_front() {
                    break Work::Step(r);
                }
                if q.draining && q.admit.is_empty() && q.resident == 0 {
                    break Work::Exit;
                }
                q = shared.cvar.wait(q).expect("worker queues lock poisoned");
            }
        };
        match work {
            Work::Exit => {
                // Wake peers so they observe the exit condition too.
                shared.cvar.notify_all();
                return;
            }
            Work::Admit(job) => {
                // Adopt the request's trace id so spans opened on this
                // worker thread stitch into the request's timeline.
                obs::set_trace_id(job.trace);
                let running = admit(shared, job);
                let mut q = shared.queues.lock().expect("worker queues lock poisoned");
                q.run.push_back(running);
                drop(q);
                shared.cvar.notify_one();
            }
            Work::Step(mut r) => {
                obs::set_trace_id(r.trace);
                step_slice(shared, &mut r);
                if r.session.finished || r.cancelled {
                    retire(shared, r);
                    let mut q = shared.queues.lock().expect("worker queues lock poisoned");
                    q.resident -= 1;
                    drop(q);
                    // May unblock admissions or the drain condition.
                    shared.cvar.notify_all();
                } else {
                    let mut q = shared.queues.lock().expect("worker queues lock poisoned");
                    q.run.push_back(r);
                    drop(q);
                    shared.cvar.notify_one();
                }
            }
        }
    }
}

/// Turn an admitted job into a resident session: prompt-cache restore when
/// possible (skipping prefill entirely), full prefill + cache fill
/// otherwise.
fn admit(shared: &Shared, job: ServeJob) -> Running {
    shared.counters.queue_wait.observe(job.queued.elapsed().as_secs_f64());
    let _span = obs::span("admit", "serve");
    // In-flight registry for incident dumps: which requests were resident
    // when a crash dump fired.  Write-only bookkeeping.
    obs::incident::track(job.id, job.req.prompt.len(), job.req.max_new_tokens);
    let key = CacheKey { mech: shared.model.mech.label(), prompt: job.req.prompt.clone() };
    let t_lookup = Instant::now();
    let cached = shared.cache.get(&key);
    shared.counters.cache_lookup.observe(t_lookup.elapsed().as_secs_f64());
    let (session, cache_hit) = match cached {
        Some(prefix) => {
            shared.counters.cache_hits.fetch_add(1, Ordering::Relaxed);
            // The thaw (f16 → f32 widening + tail re-absorb when the cold
            // tier is on) happens here, on this worker's thread — the
            // cache lock was only held for an Arc bump.
            let (states, last_logits) = prefix.thaw(&shared.model);
            let s = DecodeSession::from_prefix(job.id as usize, job.req, states, last_logits);
            (s, true)
        }
        None => {
            shared.counters.cache_misses.fetch_add(1, Ordering::Relaxed);
            let s = DecodeSession::new(&shared.model, job.id as usize, job.req);
            shared.cache.insert(key, shared.cache.freeze(&s));
            (s, false)
        }
    };
    shared
        .counters
        .cache_bytes
        .store(shared.cache.stats().bytes as u64, Ordering::Relaxed);
    shared.counters.record_arena(&shared.cache.arena_stats());
    Running {
        session,
        events: job.events,
        queued: job.queued,
        ttft_secs: None,
        cache_hit,
        cancelled: false,
        trace: job.trace,
    }
}

/// Step one session up to `slice_tokens` tokens, streaming each out.
fn step_slice(shared: &Shared, r: &mut Running) {
    let _span = obs::span("step_slice", "serve");
    for _ in 0..shared.cfg.slice_tokens {
        let t_tok = Instant::now();
        let Some(tok) = r.session.step(&shared.model) else { break };
        shared.counters.token_latency.observe(t_tok.elapsed().as_secs_f64());
        if r.ttft_secs.is_none() {
            let ttft = r.queued.elapsed().as_secs_f64();
            r.ttft_secs = Some(ttft);
            shared.counters.record_ttft(ttft);
        }
        let event = TokenEvent::Token { token: tok, text: decode_text(&[tok]) };
        if r.events.send(event).is_err() {
            // Peer disconnected: stop decoding, retire without accounting.
            r.cancelled = true;
            return;
        }
        if r.session.finished {
            return;
        }
    }
}

/// Final accounting + the terminal event.
fn retire(shared: &Shared, r: Running) {
    obs::incident::untrack(r.session.id as u64);
    if r.cancelled {
        return;
    }
    let stats = RequestStats {
        id: r.session.id as u64,
        prompt_len: r.session.prompt_len,
        new_tokens: r.session.new_tokens(),
        cache_hit: r.cache_hit,
        ttft_secs: r.ttft_secs.unwrap_or_else(|| r.queued.elapsed().as_secs_f64()),
        prefill_secs: r.session.prefill_secs,
        decode_secs: r.session.decode_secs,
        wall_secs: r.queued.elapsed().as_secs_f64(),
        generated: r.session.generated().to_vec(),
    };
    shared.counters.completed.fetch_add(1, Ordering::Relaxed);
    shared
        .counters
        .tokens_generated
        .fetch_add(stats.new_tokens as u64, Ordering::Relaxed);
    let _ = r.events.send(TokenEvent::Done(stats));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::Mechanism;
    use crate::infer::model::LmConfig;
    use crate::infer::sampler::SamplePolicy;
    use std::sync::mpsc::channel;

    fn pool(mech: Mechanism, cfg: WorkerConfig) -> (WorkerPool, Arc<ServeCounters>) {
        let lm_cfg = LmConfig { vocab: 64, d_model: 32, layers: 2, heads: 2, ff_mult: 2, seed: 2 };
        let model = Arc::new(NativeLm::new(lm_cfg, mech));
        let cache = Arc::new(PromptCache::new(16 << 20));
        let counters = Arc::new(ServeCounters::new());
        (WorkerPool::new(model, cache, Arc::clone(&counters), cfg), counters)
    }

    fn req(seed: u64, max_new: usize) -> GenRequest {
        GenRequest {
            prompt: vec![0, 9, 4, 17],
            max_new_tokens: max_new,
            policy: SamplePolicy::Temperature(0.8),
            seed,
        }
    }

    #[test]
    fn pool_serves_and_drains() {
        let (pool, counters) =
            pool(Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true }, WorkerConfig {
                workers: 3,
                slice_tokens: 2,
                max_resident: 4,
            });
        let submit = |i: u64| {
            let (tx, rx) = channel();
            pool.try_submit(
                ServeJob { id: i, req: req(i, 5), events: tx, queued: Instant::now(), trace: 0 },
                64,
            )
            .ok()
            .expect("admission under cap");
            rx
        };
        let collect = |rx: std::sync::mpsc::Receiver<TokenEvent>| {
            let mut tokens = Vec::new();
            let mut done = None;
            for ev in rx.iter() {
                match ev {
                    TokenEvent::Token { token, .. } => tokens.push(token),
                    TokenEvent::Done(stats) => done = Some(stats),
                }
            }
            (tokens, done.expect("terminal event"))
        };
        // Warm the prompt cache with one request first — submitting all six
        // cold would let several workers miss concurrently (a real, benign
        // thundering-herd property, but it would make the counters racy).
        let (tokens0, stats0) = collect(submit(0));
        assert_eq!(stats0.new_tokens, 5);
        assert_eq!(stats0.generated, tokens0);
        assert!(!stats0.cache_hit);
        let rxs: Vec<_> = (1..6u64).map(submit).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let (tokens, stats) = collect(rx);
            assert_eq!(stats.id, i as u64 + 1);
            assert_eq!(stats.new_tokens, 5);
            assert_eq!(stats.generated, tokens);
            assert!(stats.cache_hit, "warm cache must hit");
            assert!(stats.ttft_secs >= 0.0 && stats.wall_secs >= stats.ttft_secs);
        }
        pool.drain();
        assert_eq!(counters.completed.load(Ordering::Relaxed), 6);
        assert_eq!(counters.tokens_generated.load(Ordering::Relaxed), 30);
        // Same prompt 6 times through one mechanism: 1 miss, 5 hits.
        assert_eq!(counters.cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(counters.cache_hits.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn try_submit_rejects_over_cap_and_after_drain() {
        let (pool, _) = pool(Mechanism::Softmax, WorkerConfig {
            workers: 1,
            slice_tokens: 1,
            max_resident: 1,
        });
        pool.drain();
        let (tx, _rx) = channel();
        let job = ServeJob { id: 0, req: req(0, 1), events: tx, queued: Instant::now(), trace: 0 };
        assert!(pool.try_submit(job, 64).is_err(), "draining pool must reject");
    }

    #[test]
    fn disconnected_client_cancels_without_stalling() {
        let (pool, counters) = pool(Mechanism::Softmax, WorkerConfig {
            workers: 1,
            slice_tokens: 1,
            max_resident: 2,
        });
        let (tx, rx) = channel();
        drop(rx); // peer gone before the first token
        pool.try_submit(
            ServeJob { id: 0, req: req(0, 50), events: tx, queued: Instant::now(), trace: 0 },
            64,
        )
        .ok()
        .expect("admission");
        // A live request behind it must still complete.
        let (tx2, rx2) = channel();
        pool.try_submit(
            ServeJob { id: 1, req: req(1, 3), events: tx2, queued: Instant::now(), trace: 0 },
            64,
        )
        .ok()
        .expect("admission");
        let done = rx2
            .iter()
            .find_map(|ev| match ev {
                TokenEvent::Done(s) => Some(s),
                _ => None,
            })
            .expect("live request completes");
        assert_eq!(done.new_tokens, 3);
        pool.drain();
        // The cancelled request is not counted as completed.
        assert_eq!(counters.completed.load(Ordering::Relaxed), 1);
    }
}
