//! The serving gateway: request lifecycle from socket to token stream.
//!
//! Composition of the sibling modules — [`http`](crate::serve::http)
//! parses the wire, [`cache`](crate::serve::cache) skips repeated
//! prefills, [`worker`](crate::serve::worker) decodes — plus the two
//! things only the front door can do: admission control (bounded queue,
//! HTTP 429 on overflow, 503 while draining) and per-request accounting
//! (TTFT, decode tokens/sec, cache hit) reported both in-band (the final
//! chunk of every stream) and out-of-band (`GET /metrics`, `serve_request`
//! / `serve_metrics` JSONL records).
//!
//! API surface:
//!   `POST /v1/generate`  {"prompt", "max_tokens", "policy", "temperature",
//!                         "top_k", "top_p", "seed"} -> chunked stream of
//!                         `{"token","text"}` lines, then a `{"done":true}`
//!                         line with the accounting
//!   `GET /healthz`       liveness + model identity
//!   `GET /metrics`       serve counters + cache stats (JSON object)
//!
//! [`Gateway::submit`] is the same lifecycle minus HTTP — benches and
//! tests drive it in-process, so load results measure serving, not socket
//! parsing.

use std::io;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::infer::model::NativeLm;
use crate::infer::sampler::SamplePolicy;
use crate::infer::session::{decode_text, encode_prompt, GenRequest};
use crate::metrics::{json_escape, JsonlWriter, Record, ServeCounters};
use crate::obs;
use crate::serve::cache::PromptCache;
use crate::serve::http::{
    json_get, parse_json_object, Handler, HttpRequest, HttpServer, Json, Responder,
};
use crate::serve::worker::{RequestStats, ServeJob, TokenEvent, WorkerConfig, WorkerPool};

/// Gateway knobs (the `psf serve` flags map 1:1 onto these).
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    /// Listen address; port 0 binds an ephemeral port.
    pub addr: String,
    /// Decode worker threads.
    pub workers: usize,
    /// Admission-queue depth cap — beyond it, requests get 429.
    pub queue_cap: usize,
    /// Max sessions resident across workers (continuous-batching width).
    pub max_resident: usize,
    /// Tokens per worker grab (fairness/throughput dial).
    pub slice_tokens: usize,
    /// Prompt-prefix cache byte budget.
    pub cache_bytes: usize,
    /// `max_tokens` when the request omits it.
    pub default_max_tokens: usize,
    /// Hard per-request `max_tokens` ceiling.
    pub max_tokens_cap: usize,
    /// JSONL sink for per-request + closing metrics records.
    pub log_path: Option<std::path::PathBuf>,
    /// Stop after this many completed generate requests (0 = run forever)
    /// — deterministic shutdown for the CI smoke job and the example.
    pub max_requests: u64,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            addr: "127.0.0.1:0".into(),
            workers: 2,
            queue_cap: 64,
            max_resident: 8,
            slice_tokens: 4,
            cache_bytes: 64 << 20,
            default_max_tokens: 64,
            max_tokens_cap: 512,
            log_path: None,
            max_requests: 0,
        }
    }
}

/// Why a request was not admitted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rejected {
    /// Admission queue at capacity -> HTTP 429.
    QueueFull,
    /// Gateway is draining -> HTTP 503.
    Draining,
}

/// The serving gateway.  Construct once per model, then either drive it
/// in-process ([`Gateway::submit`]) or serve HTTP ([`Gateway::run_http`]).
pub struct Gateway {
    model: Arc<NativeLm>,
    cfg: GatewayConfig,
    pool: WorkerPool,
    cache: Arc<PromptCache>,
    pub counters: Arc<ServeCounters>,
    next_id: AtomicU64,
    stop: Arc<AtomicBool>,
    log: Mutex<Option<JsonlWriter>>,
    /// Actual bound address once [`Gateway::run_http`] is listening —
    /// lets embedders (example, tests) use port 0 and discover the port.
    bound: Mutex<Option<std::net::SocketAddr>>,
}

impl Gateway {
    pub fn new(model: NativeLm, cfg: GatewayConfig) -> anyhow::Result<Gateway> {
        let model = Arc::new(model);
        let cache = Arc::new(PromptCache::new(cfg.cache_bytes));
        let counters = Arc::new(ServeCounters::new());
        let pool = WorkerPool::new(
            Arc::clone(&model),
            Arc::clone(&cache),
            Arc::clone(&counters),
            WorkerConfig {
                workers: cfg.workers,
                slice_tokens: cfg.slice_tokens,
                max_resident: cfg.max_resident,
            },
        );
        let log = match &cfg.log_path {
            Some(p) => Some(JsonlWriter::create(p)?),
            None => None,
        };
        // Feed the flight recorder (inert unless started): serve gauges
        // become time series in the incident window.
        counters.register_recorder_gauges();
        Ok(Gateway {
            model,
            cfg,
            pool,
            cache,
            counters,
            next_id: AtomicU64::new(0),
            stop: Arc::new(AtomicBool::new(false)),
            log: Mutex::new(log),
            bound: Mutex::new(None),
        })
    }

    /// The listening address, once `run_http` has bound it.
    pub fn http_addr(&self) -> Option<std::net::SocketAddr> {
        *self.bound.lock().expect("bound lock poisoned")
    }

    pub fn mech_label(&self) -> String {
        self.model.mech.label()
    }

    /// Admit a request (or reject it) and return the event stream.  The
    /// full lifecycle minus HTTP: queue -> (cache | prefill) -> interleaved
    /// decode -> Done(stats).
    pub fn submit(&self, req: GenRequest) -> Result<Receiver<TokenEvent>, Rejected> {
        if self.stop.load(Ordering::SeqCst) {
            self.counters.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(Rejected::Draining);
        }
        let (tx, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        // Mint the trace id here and adopt it on the submitting thread:
        // spans the caller still has open pick it up at close, and the
        // workers inherit it through the job.
        let trace = obs::mint_trace_id(id);
        obs::set_trace_id(trace);
        let job = ServeJob { id, req, events: tx, queued: Instant::now(), trace };
        match self.pool.try_submit(job, self.cfg.queue_cap) {
            Ok(()) => {
                self.counters.admitted.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err(_job) => {
                self.counters.rejected.fetch_add(1, Ordering::Relaxed);
                Err(Rejected::QueueFull)
            }
        }
    }

    /// Serve HTTP until `max_requests` completions (or forever), then
    /// drain the workers and write the closing metrics record.  Prints the
    /// bound address on startup — the CI smoke job and the quick-start
    /// scrape it.
    pub fn run_http(self: Arc<Gateway>) -> anyhow::Result<()> {
        let server = HttpServer::bind(&self.cfg.addr)?;
        let addr = server.local_addr()?;
        *self.bound.lock().expect("bound lock poisoned") = Some(addr);
        println!("psf serve: listening on http://{addr} (mech {})", self.mech_label());
        println!(
            "psf serve: {} workers, queue cap {}, cache budget {} MiB",
            self.cfg.workers.max(1),
            self.cfg.queue_cap,
            self.cfg.cache_bytes >> 20,
        );
        let stop = Arc::clone(&self.stop);
        let handler: Arc<dyn Handler> = Arc::clone(&self) as Arc<dyn Handler>;
        server.serve(handler, stop)?;
        self.finish()
    }

    /// Drain workers and flush the closing `serve_metrics` record.  Also
    /// the programmatic shutdown for in-process use.
    pub fn finish(&self) -> anyhow::Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        self.pool.drain();
        let record = self.metrics_record();
        if let Some(w) = self.log.lock().expect("log lock poisoned").as_mut() {
            w.write(&record)?;
            w.flush()?;
        }
        eprintln!("psf serve: drained — {}", record.to_json());
        Ok(())
    }

    /// Current serve counters (cache gauges refreshed) as a JSONL record.
    pub fn metrics_record(&self) -> Record {
        let stats = self.cache.stats();
        self.counters.cache_bytes.store(stats.bytes as u64, Ordering::Relaxed);
        self.counters.record_arena(&self.cache.arena_stats());
        self.counters
            .record()
            .str("mech", self.model.mech.label())
            .i64("cache_entries", stats.entries as i64)
            .i64("cache_evictions", stats.evictions as i64)
            .i64("queue_depth", self.pool.queued() as i64)
            .i64("resident", self.pool.resident() as i64)
    }

    /// Build a GenRequest from a parsed `/v1/generate` body.
    fn parse_generate(&self, body: &str) -> Result<GenRequest, String> {
        parse_generate_body(
            body,
            &GenDefaults {
                default_max_tokens: self.cfg.default_max_tokens,
                max_tokens_cap: self.cfg.max_tokens_cap,
            },
        )
    }

    /// Shared stop flag — external signal handlers (SIGTERM/SIGINT
    /// watchers) set it to make `run_http`'s accept loop exit and drain.
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Stream one admitted request out as chunked JSON lines.
    fn stream_response(
        &self,
        rx: Receiver<TokenEvent>,
        resp: &mut Responder<'_>,
    ) -> io::Result<()> {
        resp.start_chunked(200, "application/json")?;
        for event in rx.iter() {
            match event {
                TokenEvent::Token { token, text } => {
                    resp.chunk(&token_chunk(token, &text))?;
                }
                TokenEvent::Done(stats) => {
                    self.on_done(&stats);
                    resp.chunk(&done_chunk(&stats, ""))?;
                }
            }
        }
        resp.finish()
    }

    /// Completion bookkeeping of the HTTP path: the per-request JSONL
    /// record and the `max_requests` stop condition.  The in-process
    /// [`Gateway::submit`] path does NOT run this — embedders that want
    /// the same records/stop behavior call it themselves with the
    /// `Done` stats (it is idempotent per request only in the sense that
    /// each call writes one record, so call it once).
    pub fn on_done(&self, stats: &RequestStats) {
        if let Some(w) = self.log.lock().expect("log lock poisoned").as_mut() {
            let _ = w.write(&request_record(&self.model.mech.label(), stats));
            let _ = w.flush();
        }
        if self.cfg.max_requests > 0
            && self.counters.completed.load(Ordering::Relaxed) >= self.cfg.max_requests
        {
            self.stop.store(true, Ordering::SeqCst);
        }
    }
}

impl Handler for Gateway {
    fn handle(&self, req: HttpRequest, resp: &mut Responder<'_>) -> io::Result<()> {
        // The request-target may carry a query string (`/metrics?format=..`):
        // route on the bare path.
        let (path, query) = match req.path.split_once('?') {
            Some((p, q)) => (p, q),
            None => (req.path.as_str(), ""),
        };
        match (req.method.as_str(), path) {
            ("GET", "/healthz") => {
                let a = self.cache.arena_stats();
                resp.simple(
                    200,
                    "application/json",
                    &format!(
                        "{{\"ok\":true,\"mech\":{},\"linear\":{},\"simd\":{},\"quant\":{},\
                         \"uptime_seconds\":{:.1},\
                         \"arena\":{{\"slots_live\":{},\"bytes_live\":{},\
                         \"bytes_committed\":{},\"pages\":{}}}}}",
                        json_escape(&self.model.mech.label()),
                        self.model.mech.is_linear(),
                        json_escape(crate::tensor::micro::backend_label()),
                        json_escape(crate::mem::quant::mode().label()),
                        crate::obs::uptime_secs(),
                        a.slots_live,
                        a.bytes_live,
                        a.bytes_committed,
                        a.pages,
                    ),
                )
            }
            ("GET", "/metrics") if query.split('&').any(|kv| kv == "format=prometheus") => {
                self.counters.cache_bytes.store(self.cache.stats().bytes as u64, Ordering::Relaxed);
                self.counters.record_arena(&self.cache.arena_stats());
                resp.simple(200, "text/plain; version=0.0.4", &self.counters.prometheus_text())
            }
            ("GET", "/metrics") => {
                resp.simple(200, "application/json", &self.metrics_record().to_json())
            }
            ("POST", "/v1/generate") => {
                let _span = obs::span("serve_request", "gateway");
                let gen_req = match self.parse_generate(&req.body_str()) {
                    Ok(r) => r,
                    Err(msg) => {
                        return resp.simple(
                            400,
                            "application/json",
                            &format!("{{\"error\":{}}}", json_escape(&msg)),
                        );
                    }
                };
                match self.submit(gen_req) {
                    Ok(rx) => self.stream_response(rx, resp),
                    Err(Rejected::QueueFull) => resp.simple(
                        429,
                        "application/json",
                        "{\"error\":\"admission queue full, retry later\"}",
                    ),
                    Err(Rejected::Draining) => resp.simple(
                        503,
                        "application/json",
                        "{\"error\":\"gateway is draining\"}",
                    ),
                }
            }
            (_, "/healthz" | "/metrics" | "/v1/generate") => {
                resp.simple(405, "application/json", "{\"error\":\"method not allowed\"}")
            }
            _ => resp.simple(404, "application/json", "{\"error\":\"no such route\"}"),
        }
    }
}

/// Request-shape knobs [`parse_generate_body`] needs — split out so the
/// sharded gateway (which has no `GatewayConfig`) parses identically.
pub struct GenDefaults {
    pub default_max_tokens: usize,
    pub max_tokens_cap: usize,
}

/// Build a GenRequest from a `/v1/generate` body.  One parser for every
/// gateway front-end, so single-process and sharded serving accept the
/// same request language byte for byte.
pub fn parse_generate_body(body: &str, defaults: &GenDefaults) -> Result<GenRequest, String> {
    let obj = parse_json_object(body)?;
    let prompt_text = json_get(&obj, "prompt")
        .and_then(Json::as_str)
        .ok_or("missing required string field `prompt`")?;
    let num = |key: &str, default: f64| -> Result<f64, String> {
        match json_get(&obj, key) {
            None | Some(Json::Null) => Ok(default),
            Some(v) => v.as_f64().ok_or(format!("field `{key}` must be a number")),
        }
    };
    let max_tokens = num("max_tokens", defaults.default_max_tokens as f64)? as usize;
    if max_tokens == 0 {
        return Err("`max_tokens` must be >= 1".into());
    }
    let policy_name = match json_get(&obj, "policy") {
        None | Some(Json::Null) => "greedy",
        Some(v) => v.as_str().ok_or("field `policy` must be a string")?,
    };
    let policy = SamplePolicy::from_flags(
        policy_name,
        num("temperature", 1.0)? as f32,
        num("top_k", 40.0)? as usize,
        num("top_p", 0.9)? as f32,
    )?;
    Ok(GenRequest {
        prompt: encode_prompt(prompt_text),
        max_new_tokens: max_tokens.min(defaults.max_tokens_cap),
        policy,
        seed: num("seed", 0.0)? as u64,
    })
}

/// One `{"token":..}` stream line (shared by every gateway front-end).
pub fn token_chunk(token: u32, text: &str) -> String {
    format!("{{\"token\":{},\"text\":{}}}\n", token, json_escape(text))
}

/// The closing `{"done":true,..}` stream line.  `extra` is splice-in
/// JSON appended before the closing brace (e.g. `,"runner":1`) — empty
/// for the single-process gateway, so its bytes are unchanged.
pub fn done_chunk(stats: &RequestStats, extra: &str) -> String {
    format!(
        "{{\"done\":true,\"new_tokens\":{},\"cache_hit\":{},\"ttft_ms\":{:.3},\
         \"prefill_ms\":{:.3},\"decode_tokens_per_sec\":{:.1},\"text\":{}{}}}\n",
        stats.new_tokens,
        stats.cache_hit,
        stats.ttft_secs * 1e3,
        stats.prefill_secs * 1e3,
        stats.decode_tokens_per_sec(),
        json_escape(&decode_text(&stats.generated)),
        extra,
    )
}

/// Per-request JSONL record (`kind = "serve_request"`), the serving
/// counterpart of the scheduler's `session` records.
pub(crate) fn request_record(mech_label: &str, s: &RequestStats) -> Record {
    Record::new()
        .str("kind", "serve_request")
        .str("mech", mech_label)
        .i64("id", s.id as i64)
        .i64("prompt_len", s.prompt_len as i64)
        .i64("new_tokens", s.new_tokens as i64)
        .bool("cache_hit", s.cache_hit)
        .f64("ttft_ms", s.ttft_secs * 1e3)
        .f64("prefill_ms", s.prefill_secs * 1e3)
        .f64("decode_ms", s.decode_secs * 1e3)
        .f64("decode_tokens_per_sec", s.decode_tokens_per_sec())
        .f64("wall_ms", s.wall_secs * 1e3)
}

/// Drain a submit receiver to completion, returning (tokens, stats) —
/// the in-process client loop benches and tests share.
pub fn collect_stream(rx: Receiver<TokenEvent>) -> (Vec<u32>, Option<RequestStats>) {
    let mut tokens = Vec::new();
    let mut done = None;
    for ev in rx.iter() {
        match ev {
            TokenEvent::Token { token, .. } => tokens.push(token),
            TokenEvent::Done(stats) => done = Some(stats),
        }
    }
    (tokens, done)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::Mechanism;
    use crate::infer::model::LmConfig;

    fn gateway(cfg: GatewayConfig) -> Gateway {
        let lm = LmConfig { vocab: 64, d_model: 32, layers: 2, heads: 2, ff_mult: 2, seed: 4 };
        let model = NativeLm::new(lm, Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true });
        Gateway::new(model, cfg).unwrap()
    }

    fn req(seed: u64) -> GenRequest {
        GenRequest {
            prompt: vec![0, 8, 2, 33],
            max_new_tokens: 6,
            policy: SamplePolicy::Temperature(0.9),
            seed,
        }
    }

    #[test]
    fn submit_roundtrip_and_counters() {
        let g = gateway(GatewayConfig::default());
        let (tokens, stats) = collect_stream(g.submit(req(3)).unwrap());
        let stats = stats.expect("done event");
        assert_eq!(tokens.len(), 6);
        assert_eq!(stats.generated, tokens);
        assert!(!stats.cache_hit);
        let (tokens2, stats2) = collect_stream(g.submit(req(3)).unwrap());
        assert_eq!(tokens2, tokens);
        assert!(stats2.unwrap().cache_hit);
        g.finish().unwrap();
        assert_eq!(g.counters.admitted.load(Ordering::Relaxed), 2);
        assert_eq!(g.counters.completed.load(Ordering::Relaxed), 2);
        let json = g.metrics_record().to_json();
        assert!(json.contains("\"kind\":\"serve_metrics\""), "{json}");
        assert!(json.contains("\"cache_hits\":1"), "{json}");
    }

    #[test]
    fn draining_gateway_rejects() {
        let g = gateway(GatewayConfig::default());
        g.finish().unwrap();
        assert!(matches!(g.submit(req(0)), Err(Rejected::Draining)));
        assert_eq!(g.counters.rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn parse_generate_defaults_and_validation() {
        let g = gateway(GatewayConfig { default_max_tokens: 7, ..GatewayConfig::default() });
        let r = g.parse_generate(r#"{"prompt": "hi"}"#).unwrap();
        assert_eq!(r.max_new_tokens, 7);
        assert_eq!(r.seed, 0);
        assert_eq!(r.policy, SamplePolicy::Greedy);
        assert_eq!(r.prompt, encode_prompt("hi"));
        let r = g
            .parse_generate(
                r#"{"prompt": "x", "policy": "top-p", "top_p": 0.5, "temperature": 0.7,
                   "max_tokens": 9999, "seed": 11}"#,
            )
            .unwrap();
        assert_eq!(r.policy, SamplePolicy::TopP { p: 0.5, temperature: 0.7 });
        assert_eq!(r.max_new_tokens, 512, "capped by max_tokens_cap");
        assert_eq!(r.seed, 11);
        assert!(g.parse_generate(r#"{"max_tokens": 4}"#).is_err(), "prompt required");
        assert!(g.parse_generate(r#"{"prompt": "x", "max_tokens": 0}"#).is_err());
        assert!(g.parse_generate(r#"{"prompt": "x", "policy": "banana"}"#).is_err());
        assert!(g.parse_generate("not json").is_err());
    }
}
