//! Hand-rolled HTTP/1.1 substrate over `std::net` (no hyper/tokio in this
//! environment): request parsing, plain and chunked responses, a tiny flat
//! JSON body parser, and an accept loop that hands each connection to a
//! [`Handler`] on its own thread.
//!
//! Scope is deliberately narrow — exactly what the serving gateway needs:
//! one request per connection (`Connection: close`), `Content-Length`
//! bodies only, flat JSON objects (string/number/bool/null values).  The
//! interesting serving problems (admission, caching, batching) live in the
//! sibling modules; this file stays boring on purpose.

use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Parsed request line + headers + body.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    /// Header names lowercased; values trimmed.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

/// Hard limits — a serving front-end must bound untrusted input.
const MAX_HEADER_LINE: usize = 8 * 1024;
const MAX_HEADERS: usize = 64;
const MAX_BODY: usize = 1024 * 1024;

/// Read one HTTP/1.1 request.  `Ok(None)` means the peer closed the
/// connection before sending a request line (a clean no-op).
pub fn read_request(stream: &mut TcpStream) -> io::Result<Option<HttpRequest>> {
    let mut reader = BufReader::new(stream);
    let request_line = match read_crlf_line(&mut reader)? {
        Some(l) if !l.is_empty() => l,
        _ => return Ok(None),
    };
    let mut parts = request_line.split_whitespace();
    let (method, path) = match (parts.next(), parts.next()) {
        (Some(m), Some(p)) => (m.to_string(), p.to_string()),
        _ => return Err(bad_input("malformed request line")),
    };
    let mut headers = Vec::new();
    loop {
        let line = read_crlf_line(&mut reader)?
            .ok_or_else(|| bad_input("connection closed inside headers"))?;
        if line.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(bad_input("too many headers"));
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(bad_input("malformed header line"));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let content_length = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| v.parse::<usize>())
        .transpose()
        .map_err(|_| bad_input("bad content-length"))?
        .unwrap_or(0);
    if content_length > MAX_BODY {
        return Err(bad_input("body too large"));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Some(HttpRequest { method, path, headers, body }))
}

/// Read a line terminated by `\n`, stripping a trailing `\r`.  `None` on
/// clean EOF before any byte.
fn read_crlf_line(reader: &mut impl BufRead) -> io::Result<Option<String>> {
    let mut buf = Vec::new();
    let n = reader.take(MAX_HEADER_LINE as u64 + 1).read_until(b'\n', &mut buf)?;
    if n == 0 {
        return Ok(None);
    }
    if n > MAX_HEADER_LINE {
        return Err(bad_input("header line too long"));
    }
    while buf.last() == Some(&b'\n') || buf.last() == Some(&b'\r') {
        buf.pop();
    }
    String::from_utf8(buf).map(Some).map_err(|_| bad_input("non-utf8 header"))
}

fn bad_input(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Response writer for one connection: either one `simple` response or a
/// `start_chunked` / `chunk`* / `finish` streaming sequence.
pub struct Responder<'a> {
    stream: &'a mut TcpStream,
    chunked: bool,
}

impl<'a> Responder<'a> {
    pub fn new(stream: &'a mut TcpStream) -> Responder<'a> {
        Responder { stream, chunked: false }
    }

    /// One-shot response with a `Content-Length` body.
    pub fn simple(&mut self, status: u16, content_type: &str, body: &str) -> io::Result<()> {
        write!(
            self.stream,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
            status,
            status_text(status),
            content_type,
            body.len(),
            body,
        )?;
        self.stream.flush()
    }

    /// Begin a chunked (streaming) response — the per-token path.
    pub fn start_chunked(&mut self, status: u16, content_type: &str) -> io::Result<()> {
        self.chunked = true;
        write!(
            self.stream,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
            status,
            status_text(status),
            content_type,
        )?;
        self.stream.flush()
    }

    /// Emit one chunk and flush it — each generated token streams out as
    /// soon as the worker produces it.
    pub fn chunk(&mut self, data: &str) -> io::Result<()> {
        debug_assert!(self.chunked, "chunk() before start_chunked()");
        if data.is_empty() {
            return Ok(()); // an empty chunk would terminate the stream
        }
        write!(self.stream, "{:x}\r\n{}\r\n", data.len(), data)?;
        self.stream.flush()
    }

    /// Terminate the chunked stream.
    pub fn finish(&mut self) -> io::Result<()> {
        debug_assert!(self.chunked, "finish() before start_chunked()");
        self.stream.write_all(b"0\r\n\r\n")?;
        self.stream.flush()
    }
}

/// Connection handler: the gateway implements this to route requests.
pub trait Handler: Send + Sync + 'static {
    fn handle(&self, req: HttpRequest, resp: &mut Responder<'_>) -> io::Result<()>;
}

/// Minimal threaded HTTP server: accept loop + one thread per connection.
pub struct HttpServer {
    listener: TcpListener,
}

impl HttpServer {
    pub fn bind(addr: &str) -> io::Result<HttpServer> {
        Ok(HttpServer { listener: TcpListener::bind(addr)? })
    }

    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Accept until `stop` flips, handing each connection to `handler` on
    /// its own thread; joins all connection threads before returning so the
    /// caller can drain workers with no responses still in flight.
    pub fn serve(self, handler: Arc<dyn Handler>, stop: Arc<AtomicBool>) -> io::Result<()> {
        self.listener.set_nonblocking(true)?;
        let mut threads: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let handler = Arc::clone(&handler);
                    threads.push(std::thread::spawn(move || {
                        handle_connection(stream, handler);
                    }));
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e),
            }
            threads.retain(|t| !t.is_finished());
        }
        for t in threads {
            let _ = t.join();
        }
        Ok(())
    }
}

fn handle_connection(mut stream: TcpStream, handler: Arc<dyn Handler>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    // A client that stops reading its response must not pin this thread
    // forever: once the send buffer fills, a write blocks at most this
    // long, the handler sees the error, and dropping the event receiver
    // cancels the decode — without this, one stalled reader would also
    // wedge shutdown (serve() joins every connection thread).
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    // Blocking I/O per connection (the listener's nonblocking flag is
    // inherited on some platforms; undo it explicitly).
    let _ = stream.set_nonblocking(false);
    match read_request(&mut stream) {
        Ok(Some(req)) => {
            let mut resp = Responder::new(&mut stream);
            // A handler I/O error means the peer went away mid-stream; the
            // worker notices via its closed channel, nothing to do here.
            let _ = handler.handle(req, &mut resp);
        }
        Ok(None) => {}
        Err(_) => {
            let mut resp = Responder::new(&mut stream);
            let _ = resp.simple(400, "application/json", "{\"error\":\"bad request\"}");
        }
    }
}

// ------------------------------------------------------------- flat JSON

/// A flat JSON scalar (the only value shapes the serve API uses).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Str(String),
    Num(f64),
    Bool(bool),
    Null,
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
}

/// Parse a flat JSON object (`{"k": "v", "n": 1, "b": true}`) — nested
/// objects/arrays are rejected, which keeps the parser ~100 lines and the
/// API surface honest about what it accepts.
pub fn parse_json_object(s: &str) -> Result<Vec<(String, Json)>, String> {
    let mut p = JsonParser { bytes: s.as_bytes(), pos: 0 };
    p.skip_ws();
    p.expect(b'{')?;
    let mut out = Vec::new();
    p.skip_ws();
    if p.peek() == Some(b'}') {
        p.pos += 1;
    } else {
        loop {
            p.skip_ws();
            let key = p.string()?;
            p.skip_ws();
            p.expect(b':')?;
            p.skip_ws();
            let value = p.value()?;
            out.push((key, value));
            p.skip_ws();
            match p.next() {
                Some(b',') => continue,
                Some(b'}') => break,
                _ => return Err("expected `,` or `}`".into()),
            }
        }
    }
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err("trailing bytes after object".into());
    }
    Ok(out)
}

/// Fetch a key from a parsed flat object.
pub fn json_get<'a>(obj: &'a [(String, Json)], key: &str) -> Option<&'a Json> {
    obj.iter().find(|(k, _)| k == key).map(|(_, v)| v)
}

struct JsonParser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> JsonParser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn next(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, want: u8) -> Result<(), String> {
        match self.next() {
            Some(b) if b == want => Ok(()),
            other => Err(format!("expected `{}`, got {:?}", want as char, other.map(char::from))),
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'{') | Some(b'[') => Err("nested objects/arrays not supported".into()),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(format!("bad literal (expected {word})"))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).map_err(|e| e.to_string())?;
        text.parse::<f64>().map(Json::Num).map_err(|_| format!("bad number `{text}`"))
    }

    /// Four hex digits of a `\u` escape (cursor already past the `u`).
    fn hex4(&mut self) -> Result<u32, String> {
        if self.pos + 4 > self.bytes.len() {
            return Err("truncated \\u escape".into());
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|e| e.to_string())?;
        let code = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape".to_string())?;
        self.pos += 4;
        Ok(code)
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.next() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.next() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        if (0xD800..0xDC00).contains(&hi) {
                            // High surrogate: JSON escapes non-BMP scalars
                            // as a \uD8xx\uDCxx pair (e.g. emoji from any
                            // ensure_ascii encoder) — recombine it.
                            if self.bytes.get(self.pos) == Some(&b'\\')
                                && self.bytes.get(self.pos + 1) == Some(&b'u')
                            {
                                self.pos += 2;
                                let lo = self.hex4()?;
                                if (0xDC00..0xE000).contains(&lo) {
                                    let c = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                    out.push(char::from_u32(c).unwrap_or('\u{fffd}'));
                                } else {
                                    // Unpaired high + some other escape:
                                    // replacement for the orphan, keep the
                                    // second scalar.
                                    out.push('\u{fffd}');
                                    out.push(char::from_u32(lo).unwrap_or('\u{fffd}'));
                                }
                            } else {
                                out.push('\u{fffd}');
                            }
                        } else {
                            // from_u32 is None exactly for unpaired low
                            // surrogates here.
                            out.push(char::from_u32(hi).unwrap_or('\u{fffd}'));
                        }
                    }
                    other => return Err(format!("bad escape {:?}", other.map(char::from))),
                },
                // Multi-byte UTF-8: the request body was validated as &str,
                // so continuation bytes are structurally sound — copy the
                // whole scalar through.
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    let len = utf8_len(b);
                    let start = self.pos - 1;
                    if start + len > self.bytes.len() {
                        return Err("truncated utf-8 scalar".into());
                    }
                    let s = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|e| e.to_string())?;
                    out.push_str(s);
                    self.pos = start + len;
                }
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn parse_flat_object() {
        let obj = parse_json_object(
            r#"{"prompt": "hi \"there\"", "max_tokens": 32, "greedy": true, "x": null, "t": 0.8}"#,
        )
        .unwrap();
        assert_eq!(json_get(&obj, "prompt").unwrap().as_str().unwrap(), "hi \"there\"");
        assert_eq!(json_get(&obj, "max_tokens").unwrap().as_f64().unwrap(), 32.0);
        assert_eq!(json_get(&obj, "greedy"), Some(&Json::Bool(true)));
        assert_eq!(json_get(&obj, "x"), Some(&Json::Null));
        assert_eq!(json_get(&obj, "t").unwrap().as_f64().unwrap(), 0.8);
        assert!(json_get(&obj, "missing").is_none());
    }

    #[test]
    fn parse_unicode_and_escapes() {
        let obj = parse_json_object(r#"{"s": "café ← ok\n"}"#).unwrap();
        assert_eq!(json_get(&obj, "s").unwrap().as_str().unwrap(), "café ← ok\n");
        // \u escapes: BMP scalar, and a surrogate pair for a non-BMP one
        // (how ensure_ascii encoders ship emoji).
        let obj = parse_json_object(r#"{"s": "\u00e9 \ud83d\ude00"}"#).unwrap();
        assert_eq!(json_get(&obj, "s").unwrap().as_str().unwrap(), "é 😀");
        // Orphan surrogates degrade to U+FFFD instead of corrupting state.
        let obj = parse_json_object(r#"{"s": "\ud83dx"}"#).unwrap();
        assert_eq!(json_get(&obj, "s").unwrap().as_str().unwrap(), "\u{fffd}x");
    }

    #[test]
    fn parse_rejects_nested_and_garbage() {
        assert!(parse_json_object(r#"{"a": {"b": 1}}"#).is_err());
        assert!(parse_json_object(r#"{"a": [1]}"#).is_err());
        assert!(parse_json_object(r#"{"a": 1} trailing"#).is_err());
        assert!(parse_json_object("not json").is_err());
        assert!(parse_json_object(r#"{"a""#).is_err());
    }

    #[test]
    fn parse_empty_object() {
        assert!(parse_json_object("{}").unwrap().is_empty());
        assert!(parse_json_object(" { } ").unwrap().is_empty());
    }

    #[test]
    fn request_roundtrip_over_loopback() {
        // Raw socket pair: write a request, parse it, answer it, read the
        // answer — the full wire path with no gateway involved.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let body = r#"{"prompt":"x"}"#;
            write!(
                s,
                "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
                body.len(),
                body
            )
            .unwrap();
            let mut out = String::new();
            s.read_to_string(&mut out).unwrap();
            out
        });
        let (mut stream, _) = listener.accept().unwrap();
        let req = read_request(&mut stream).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.body_str(), r#"{"prompt":"x"}"#);
        let mut resp = Responder::new(&mut stream);
        resp.start_chunked(200, "application/json").unwrap();
        resp.chunk("{\"token\":1}\n").unwrap();
        resp.chunk("{\"done\":true}\n").unwrap();
        resp.finish().unwrap();
        drop(stream);
        let got = client.join().unwrap();
        assert!(got.starts_with("HTTP/1.1 200 OK\r\n"), "{got}");
        assert!(got.contains("Transfer-Encoding: chunked"), "{got}");
        assert!(got.contains("{\"token\":1}"), "{got}");
        assert!(got.contains("{\"done\":true}"), "{got}");
        assert!(got.ends_with("0\r\n\r\n"), "{got}");
    }

    #[test]
    fn read_request_handles_eof_and_garbage() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // Clean EOF before any bytes -> Ok(None).
        let c = std::thread::spawn(move || drop(TcpStream::connect(addr).unwrap()));
        let (mut stream, _) = listener.accept().unwrap();
        c.join().unwrap();
        assert!(read_request(&mut stream).unwrap().is_none());
        // Garbage request line -> error.
        let addr = listener.local_addr().unwrap();
        let c = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"garbage\r\n\r\n").unwrap();
            s.shutdown(std::net::Shutdown::Write).unwrap();
        });
        let (mut stream, _) = listener.accept().unwrap();
        c.join().unwrap();
        assert!(read_request(&mut stream).is_err());
    }
}
