//! L3 coordinator: the training orchestration layer.
//!
//! The paper's *system* contribution is the linear-time attention stack
//! (L1/L2); the coordinator is the rust layer that drives it end to end:
//!
//! * [`trainer`] — single-worker loop over the fused AOT train step with
//!   eval cadence, checkpointing, NaN guard, and loss-curve logging;
//! * [`dataparallel`] — simulated synchronous data-parallel training
//!   over the native training subsystem (exact pairwise-tree allreduce
//!   of `train::Params` gradients) + microbatch gradient accumulation
//!   for the paper's 1M-token batch protocol (`psf dp-train`);
//! * [`evaluator`] — test perplexity and multiple-choice likelihood
//!   scoring (Table 1's downstream-QA analog);
//! * [`task_runner`] — Appendix F synthetic tasks (Selective Copying,
//!   Induction Heads) with exact-match accuracy curves.
//!
//! Python never runs here: every compute graph was AOT-lowered by
//! `make artifacts` and is executed via `crate::runtime`.

pub mod dataparallel;
pub mod evaluator;
pub mod task_runner;
pub mod trainer;

pub use dataparallel::{allreduce_tree, shard_stream, DataParallel, DpStepStats};
pub use evaluator::{gen_cloze_questions, perplexity, score_mcq, McqQuestion};
pub use task_runner::{eval_accuracy, run_task, Accuracy, TaskRunnerConfig, TaskSource, TaskSummary};
pub use trainer::{RunSummary, Trainer, TrainerConfig};
