//! Single-worker training loop over the fused AOT train step.
//!
//! The hot path moves exactly one token batch to the device per step and
//! reads the 8-byte stats output back; the fused state vector never leaves
//! the device except at checkpoint / eval boundaries.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;

use crate::checkpoint::Checkpoint;
use crate::data::batcher::Batcher;
use crate::metrics::{Record, RunLogger};
use crate::runtime::ModelRuntime;

/// Trainer configuration (run shape; the optimizer schedule is baked into
/// the train artifact by aot.py).
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub steps: u64,
    /// Evaluate test perplexity every `eval_every` steps (0 = never).
    pub eval_every: u64,
    /// Batches averaged per evaluation.
    pub eval_batches: usize,
    /// Checkpoint every `ckpt_every` steps into `run_dir` (0 = never).
    pub ckpt_every: u64,
    /// Console echo cadence for the logger (0 = silent).
    pub echo_every: u64,
    /// Where run logs / checkpoints go (None = no persistence).
    pub run_dir: Option<PathBuf>,
    /// Abort the run if loss goes non-finite.
    pub nan_guard: bool,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            steps: 100,
            eval_every: 0,
            eval_batches: 4,
            ckpt_every: 0,
            echo_every: 10,
            run_dir: None,
            nan_guard: true,
        }
    }
}

/// Outcome of a training run.
#[derive(Clone, Debug, Default)]
pub struct RunSummary {
    pub steps_run: u64,
    pub final_loss: f32,
    pub final_loss_ema: f64,
    /// (step, test NLL) at every eval point.
    pub evals: Vec<(u64, f32)>,
    pub wall_secs: f64,
    pub tokens_seen: u64,
    pub aborted_nonfinite: bool,
}

impl RunSummary {
    pub fn final_perplexity(&self) -> f64 {
        self.evals
            .last()
            .map(|&(_, nll)| (nll as f64).exp())
            .unwrap_or(f64::NAN)
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_seen as f64 / self.wall_secs.max(1e-9)
    }

    pub fn steps_per_sec(&self) -> f64 {
        self.steps_run as f64 / self.wall_secs.max(1e-9)
    }
}

/// Single-worker trainer: model runtime + train/test batch sources.
pub struct Trainer<'a> {
    pub model: &'a mut ModelRuntime,
    pub train: Batcher,
    pub test: Option<Batcher>,
    pub cfg: TrainerConfig,
}

impl<'a> Trainer<'a> {
    pub fn new(
        model: &'a mut ModelRuntime,
        train: Batcher,
        test: Option<Batcher>,
        cfg: TrainerConfig,
    ) -> Self {
        Trainer { model, train, test, cfg }
    }

    /// Run the configured number of steps; returns the loss curve summary.
    pub fn run(&mut self) -> Result<RunSummary> {
        let log_path = self.cfg.run_dir.as_ref().map(|d| d.join("train.jsonl"));
        if let Some(dir) = &self.cfg.run_dir {
            std::fs::create_dir_all(dir)?;
        }
        let mut logger = RunLogger::new(log_path.as_deref(), self.cfg.echo_every)?;
        let mut summary = RunSummary::default();
        let tokens_per_step = (self.model.batch() * (self.model.ctx() + 1)) as u64;
        let t0 = Instant::now();

        for _ in 0..self.cfg.steps {
            let batch = self.train.next_batch();
            let stats = self.model.train_step(&batch.tokens)?;
            summary.steps_run += 1;
            summary.tokens_seen += tokens_per_step;
            summary.final_loss = stats.loss;
            logger.log_step(stats.step, stats.loss as f64, Record::new())?;

            if self.cfg.nan_guard && !stats.loss.is_finite() {
                eprintln!("nan guard tripped at step {}", stats.step);
                summary.aborted_nonfinite = true;
                break;
            }
            if self.cfg.eval_every > 0 && stats.step % self.cfg.eval_every == 0 {
                if let Some(nll) = self.eval()? {
                    summary.evals.push((stats.step, nll));
                }
            }
            if self.cfg.ckpt_every > 0 && stats.step % self.cfg.ckpt_every == 0 {
                self.save_checkpoint(stats.step)?;
            }
        }

        // Always close with a final eval if a test stream exists.
        if self
            .test
            .as_ref()
            .map(|_| summary.evals.last().map(|&(s, _)| s) != Some(summary.steps_run))
            .unwrap_or(false)
        {
            if let Some(nll) = self.eval()? {
                summary.evals.push((summary.steps_run, nll));
            }
        }

        summary.wall_secs = t0.elapsed().as_secs_f64();
        summary.final_loss_ema = logger.final_ema().unwrap_or(f64::NAN);
        logger.finish()?;
        Ok(summary)
    }

    /// Mean test NLL over `eval_batches` batches.
    pub fn eval(&mut self) -> Result<Option<f32>> {
        let test = match &mut self.test {
            Some(t) => t,
            None => return Ok(None),
        };
        let mut total = 0.0f32;
        for _ in 0..self.cfg.eval_batches.max(1) {
            total += self.model.eval_loss(&test.next_batch().tokens)?;
        }
        Ok(Some(total / self.cfg.eval_batches.max(1) as f32))
    }

    fn save_checkpoint(&self, step: u64) -> Result<()> {
        let dir = match &self.cfg.run_dir {
            Some(d) => d,
            None => return Ok(()),
        };
        let state = self.model.state_to_host()?;
        Checkpoint::new(step)
            .with("state", state)
            .save(&dir.join(format!("ckpt_{step:06}.bin")))?;
        Ok(())
    }

    /// Restore model state from a checkpoint file.
    pub fn restore(&mut self, path: &std::path::Path) -> Result<u64> {
        let ckpt = Checkpoint::load(path)?;
        let state = ckpt
            .get("state")
            .ok_or_else(|| anyhow::anyhow!("checkpoint has no `state` section"))?;
        self.model.set_state(state)?;
        Ok(ckpt.step)
    }
}
