//! Simulated synchronous data-parallel training (the paper's 32-TPU
//! protocol) + microbatch gradient accumulation.
//!
//! Real multi-host collectives are not available on a single CPU PJRT
//! client, so the coordinator *simulates the topology while keeping the
//! math exact*: synchronous data-parallel SGD keeps every replica's
//! parameters identical, so one device-resident state plus W independent
//! gradient computations — averaged with an on-device allreduce tree and
//! applied once — produces bit-for-bit the update a W-worker cluster
//! performs.  Each worker owns a disjoint shard of the batch stream.
//!
//! The same grads/gradstep factoring gives microbatch gradient
//! accumulation: A microbatches are summed before a single optimizer step,
//! enabling "1M-token batch" protocols that exceed device memory.

use anyhow::Result;
use xla::PjRtBuffer;

use crate::data::batcher::Batcher;
use crate::metrics::{Record, RunLogger};
use crate::runtime::{ops, ModelRuntime, StepStats};

/// Shard a token stream into `workers` disjoint contiguous shards.
pub fn shard_stream(stream: &[u32], workers: usize) -> Vec<&[u32]> {
    assert!(workers > 0);
    let per = stream.len() / workers;
    (0..workers).map(|w| &stream[w * per..(w + 1) * per]).collect()
}

/// Synchronous data-parallel coordinator.
pub struct DataParallel<'a> {
    pub model: &'a mut ModelRuntime,
    /// One batch source per simulated worker (disjoint shards).
    pub workers: Vec<Batcher>,
    /// Microbatches accumulated per worker before the sync point.
    pub accum: usize,
}

impl<'a> DataParallel<'a> {
    pub fn new(model: &'a mut ModelRuntime, workers: Vec<Batcher>, accum: usize) -> Self {
        assert!(!workers.is_empty());
        assert!(accum >= 1);
        DataParallel { model, workers, accum }
    }

    /// Build from a single stream, sharding it across `workers` workers.
    pub fn from_stream(
        model: &'a mut ModelRuntime,
        stream: &[u32],
        workers: usize,
        accum: usize,
        seed: u64,
    ) -> Self {
        let batch = model.batch();
        let seq = model.ctx() + 1;
        let batchers = shard_stream(stream, workers)
            .into_iter()
            .enumerate()
            .map(|(w, shard)| Batcher::new(shard, batch, seq, seed ^ (w as u64) << 32))
            .collect();
        Self::new(model, batchers, accum)
    }

    /// Number of simulated workers.
    pub fn world_size(&self) -> usize {
        self.workers.len()
    }

    /// Tokens consumed per global step.
    pub fn tokens_per_step(&self) -> u64 {
        (self.model.batch() * (self.model.ctx() + 1) * self.workers.len() * self.accum) as u64
    }

    /// One global step: every worker computes `accum` microbatch gradients,
    /// the (W * A) gradient vectors are averaged on-device, and a single
    /// optimizer update is applied.  Returns post-update stats whose loss
    /// is the mean microbatch loss (the grad vector's fused loss slot is
    /// averaged alongside the gradients).
    pub fn step(&mut self) -> Result<StepStats> {
        let n = self.model.grad_dim();
        let mut acc: Option<PjRtBuffer> = None;
        let mut count = 0usize;
        for w in 0..self.workers.len() {
            for _ in 0..self.accum {
                let batch = self.workers[w].next_batch();
                let g = self.model.grad_loss(&batch.tokens)?;
                acc = Some(match acc {
                    None => g,
                    Some(a) => ops::add(&a, &g, n)?,
                });
                count += 1;
            }
        }
        let avg = ops::scale(&acc.expect("at least one worker"), 1.0 / count as f32, n)?;
        self.model.apply_gradvec(&avg)
    }

    /// Run `steps` global steps with logging; returns (final stats, curve).
    pub fn run(
        &mut self,
        steps: u64,
        logger: &mut RunLogger,
    ) -> Result<(StepStats, Vec<(u64, f32)>)> {
        let mut curve = Vec::with_capacity(steps as usize);
        let mut last = StepStats { step: 0, loss: f32::NAN };
        for _ in 0..steps {
            last = self.step()?;
            curve.push((last.step, last.loss));
            logger.log_step(
                last.step,
                last.loss as f64,
                Record::new().i64("workers", self.workers.len() as i64),
            )?;
        }
        Ok((last, curve))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_are_disjoint_and_cover_prefix() {
        let stream: Vec<u32> = (0..100).collect();
        let shards = shard_stream(&stream, 3);
        assert_eq!(shards.len(), 3);
        assert_eq!(shards.iter().map(|s| s.len()).sum::<usize>(), 99);
        assert_eq!(shards[0][0], 0);
        assert_eq!(shards[1][0], 33);
        assert_eq!(shards[2][0], 66);
    }

    #[test]
    #[should_panic]
    fn zero_workers_panics() {
        shard_stream(&[1, 2, 3], 0);
    }
}
