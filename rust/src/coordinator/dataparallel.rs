//! Simulated synchronous data-parallel training (the paper's 32-TPU
//! protocol) + microbatch gradient accumulation, over the **native**
//! training subsystem (`train/` — hand-written backward passes through
//! the kernel core).
//!
//! Real multi-host collectives are not available here, so the
//! coordinator *simulates the topology while keeping the math exact*:
//! synchronous data-parallel SGD keeps every replica's parameters
//! identical, so one resident model plus W independent gradient
//! computations — combined with an allreduce tree and applied once —
//! produces bit-for-bit the update a W-worker cluster performs.  Each
//! worker owns a disjoint contiguous shard of the token stream.
//!
//! The same grads/step factoring gives microbatch gradient
//! accumulation: A microbatches are summed per worker before the sync
//! point, enabling "1M-token batch" protocols that exceed memory.
//!
//! Determinism: the tree combines workers in a fixed pairwise-halving
//! order and every per-worker sum is sequential, so a (stream, seed,
//! W, A) tuple yields one exact parameter trajectory — and with
//! W = A = 1 the whole apparatus collapses to `compute_grads` +
//! `AdamW::step`, bitwise (the tests pin both properties).

use anyhow::Result;

use crate::data::batcher::Batcher;
use crate::infer::{NativeLm, Params};
use crate::metrics::{Record, RunLogger};
use crate::train::backprop::{compute_grads, TrainExample};
use crate::train::optim::{AdamW, OptimConfig, StepInfo};

/// Shard a token stream into `workers` disjoint contiguous shards.
pub fn shard_stream(stream: &[u32], workers: usize) -> Vec<&[u32]> {
    assert!(workers > 0);
    let per = stream.len() / workers;
    (0..workers).map(|w| &stream[w * per..(w + 1) * per]).collect()
}

/// Sum gradient vectors with a pairwise-halving tree — the association
/// order a bandwidth-optimal allreduce uses, fixed here so the f32 sum
/// is one deterministic function of the inputs (never claim order).
pub fn allreduce_tree(mut parts: Vec<Params>) -> Params {
    assert!(!parts.is_empty(), "allreduce over zero workers");
    while parts.len() > 1 {
        let half = parts.len().div_ceil(2);
        let tail = parts.split_off(half);
        for (i, t) in tail.into_iter().enumerate() {
            parts[i].add_scaled(&t, 1.0);
        }
    }
    parts.pop().expect("tree root")
}

/// Post-step statistics of one global data-parallel step.
#[derive(Clone, Copy, Debug)]
pub struct DpStepStats {
    /// Optimizer step count *after* this update.
    pub step: u64,
    /// Mean microbatch loss across the (W · A) gradient computations.
    pub loss: f64,
    pub lr: f32,
    pub grad_norm: f64,
}

/// Synchronous data-parallel coordinator over a native model.
pub struct DataParallel<'a> {
    pub model: &'a mut NativeLm,
    /// One batch source per simulated worker (disjoint shards).
    pub workers: Vec<Batcher>,
    /// Microbatches accumulated per worker before the sync point.
    pub accum: usize,
    opt: AdamW,
}

impl<'a> DataParallel<'a> {
    pub fn new(
        model: &'a mut NativeLm,
        workers: Vec<Batcher>,
        accum: usize,
        optim: OptimConfig,
    ) -> Self {
        assert!(!workers.is_empty());
        assert!(accum >= 1);
        let opt = AdamW::new(optim, model.params());
        DataParallel { model, workers, accum, opt }
    }

    /// Build from a single stream, sharding it across `workers` workers.
    /// `seq` is ctx + 1 (each row carries its shifted target).
    #[allow(clippy::too_many_arguments)]
    pub fn from_stream(
        model: &'a mut NativeLm,
        stream: &[u32],
        workers: usize,
        batch: usize,
        seq: usize,
        accum: usize,
        seed: u64,
        optim: OptimConfig,
    ) -> Self {
        let batchers = shard_stream(stream, workers)
            .into_iter()
            .enumerate()
            .map(|(w, shard)| Batcher::new(shard, batch, seq, seed ^ (w as u64) << 32))
            .collect();
        Self::new(model, batchers, accum, optim)
    }

    /// Number of simulated workers.
    pub fn world_size(&self) -> usize {
        self.workers.len()
    }

    /// Tokens consumed per global step.
    pub fn tokens_per_step(&self) -> u64 {
        self.workers
            .iter()
            .map(|b| (b.batch_size() * b.seq_len()) as u64)
            .sum::<u64>()
            * self.accum as u64
    }

    /// One global step: every worker computes `accum` microbatch
    /// gradients (each already mean-normalized by its counted
    /// positions, exactly as single-worker training does), the W
    /// per-worker sums are allreduced, the result is scaled to the
    /// mean over all (W · A) microbatches, and a single optimizer
    /// update is applied.
    pub fn step(&mut self) -> Result<DpStepStats> {
        let micro = self.workers.len() * self.accum;
        let accum = self.accum;
        let model = &*self.model;
        let mut parts: Vec<Params> = Vec::with_capacity(self.workers.len());
        let mut loss_sum = 0.0f64;
        for worker in self.workers.iter_mut() {
            let mut acc: Option<Params> = None;
            for _ in 0..accum {
                let examples = next_examples(worker);
                let (g, stats) = compute_grads(model, &examples);
                loss_sum += stats.loss;
                acc = Some(match acc {
                    None => g,
                    Some(mut a) => {
                        a.add_scaled(&g, 1.0);
                        a
                    }
                });
            }
            parts.push(acc.expect("accum >= 1"));
        }
        let mut avg = allreduce_tree(parts);
        avg.scale_in_place(1.0 / micro as f32);
        let info: StepInfo = self.opt.step(self.model.params_mut(), &avg);
        Ok(DpStepStats {
            step: self.opt.step_count(),
            loss: loss_sum / micro as f64,
            lr: info.lr,
            grad_norm: info.grad_norm,
        })
    }

    /// Run `steps` global steps with logging; returns (final stats, curve).
    pub fn run(
        &mut self,
        steps: u64,
        logger: &mut RunLogger,
    ) -> Result<(DpStepStats, Vec<(u64, f64)>)> {
        let mut curve = Vec::with_capacity(steps as usize);
        let mut last = DpStepStats { step: 0, loss: f64::NAN, lr: 0.0, grad_norm: 0.0 };
        for _ in 0..steps {
            last = self.step()?;
            curve.push((last.step, last.loss));
            logger.log_step(
                last.step,
                last.loss,
                Record::new()
                    .i64("workers", self.workers.len() as i64)
                    .i64("accum", self.accum as i64)
                    .f64("grad_norm", last.grad_norm),
            )?;
        }
        Ok((last, curve))
    }
}

/// One worker's next microbatch as training examples (byte-level LM
/// convention shared with `train::loop`: token 0 is padding, so only
/// non-pad targets carry loss).
fn next_examples(b: &mut Batcher) -> Vec<TrainExample> {
    let bt = b.next_batch();
    (0..bt.batch)
        .map(|r| {
            let tokens: Vec<u32> = bt.row(r).iter().map(|&t| t as u32).collect();
            let mask = tokens[1..].iter().map(|&t| t != 0).collect();
            TrainExample { tokens, mask }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::Mechanism;
    use crate::infer::LmConfig;

    #[test]
    fn shards_are_disjoint_and_cover_prefix() {
        let stream: Vec<u32> = (0..100).collect();
        let shards = shard_stream(&stream, 3);
        assert_eq!(shards.len(), 3);
        assert_eq!(shards.iter().map(|s| s.len()).sum::<usize>(), 99);
        assert_eq!(shards[0][0], 0);
        assert_eq!(shards[1][0], 33);
        assert_eq!(shards[2][0], 66);
    }

    #[test]
    #[should_panic]
    fn zero_workers_panics() {
        shard_stream(&[1, 2, 3], 0);
    }

    fn tiny_model(seed: u64) -> NativeLm {
        let cfg = LmConfig { vocab: 32, d_model: 16, layers: 1, heads: 2, ff_mult: 2, seed };
        NativeLm::new(cfg, Mechanism::Polysketch { r: 4, p: 4, block: 8, local: true })
    }

    fn stream(len: usize) -> Vec<u32> {
        // Byte-level-style tokens, no zeros (zero = pad = no loss).
        (0..len as u32).map(|i| 1 + (i * 7) % 31).collect()
    }

    #[test]
    fn tree_of_identical_parts_is_exact_multiple() {
        let model = tiny_model(11);
        let ex = TrainExample {
            tokens: (0..17u32).map(|i| 1 + (i * 5) % 31).collect(),
            mask: vec![true; 16],
        };
        let (g, _) = compute_grads(&model, &[ex]);
        let total = allreduce_tree(vec![g.clone(), g.clone(), g.clone(), g.clone()]);
        // x+x and 2x+2x are exact in binary fp, so the tree of four
        // identical parts must be bitwise 4·g.
        let mut four = g;
        four.scale_in_place(4.0);
        assert_eq!(total, four);
    }

    #[test]
    fn world_one_matches_single_worker_training_bitwise() {
        let tokens = stream(33 * 8);
        let seq = 9; // ctx 8 + shifted target
        let optim = OptimConfig { lr: 1e-2, warmup: 1, total_steps: 4, ..Default::default() };

        // Reference: the exact sequential path DataParallel must equal.
        let mut reference = tiny_model(7);
        let mut ref_batcher = Batcher::new(shard_stream(&tokens, 1)[0], 4, seq, 42);
        let mut ref_opt = AdamW::new(optim.clone(), reference.params());
        for _ in 0..4 {
            let examples = next_examples(&mut ref_batcher);
            let (mut g, _) = compute_grads(&reference, &examples);
            g.scale_in_place(1.0); // the W·A=1 mean is a no-op, bitwise
            ref_opt.step(reference.params_mut(), &g);
        }

        let mut model = tiny_model(7);
        let mut dp = DataParallel::from_stream(&mut model, &tokens, 1, 4, seq, 1, 42, optim);
        for _ in 0..4 {
            dp.step().unwrap();
        }
        assert_eq!(model.params(), reference.params());
    }

    #[test]
    fn two_workers_step_finite_and_deterministic() {
        let tokens = stream(40 * 9);
        let seq = 9;
        let optim = OptimConfig { lr: 5e-3, warmup: 1, total_steps: 3, ..Default::default() };
        let run = |seed: u64| {
            let mut model = tiny_model(seed);
            let mut dp =
                DataParallel::from_stream(&mut model, &tokens, 2, 2, seq, 2, 42, optim.clone());
            assert_eq!(dp.world_size(), 2);
            assert_eq!(dp.tokens_per_step(), 2 * 2 * 9 * 2);
            let mut losses = Vec::new();
            for _ in 0..3 {
                let s = dp.step().unwrap();
                assert!(s.loss.is_finite());
                assert!(s.grad_norm.is_finite());
                losses.push(s.loss);
            }
            let named: Vec<Vec<u32>> = model
                .params()
                .named()
                .iter()
                .map(|(_, t)| t.data().iter().map(|v| v.to_bits()).collect())
                .collect();
            (losses, named)
        };
        let (l1, p1) = run(7);
        let (l2, p2) = run(7);
        assert_eq!(l1, l2);
        assert_eq!(p1, p2, "same inputs must give bitwise-identical trajectories");
    }
}
