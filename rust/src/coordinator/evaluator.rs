//! Evaluation: test-set perplexity and multiple-choice likelihood scoring.
//!
//! The paper's downstream tasks (HellaSwag / PIQA / Physics, Table 1) are
//! multiple-choice: each candidate completion is scored by the model's
//! log-likelihood and the argmax is compared with the gold answer.  The
//! datasets themselves are not available offline, so `gen_cloze_questions`
//! builds the synthetic analog (DESIGN.md §4): cloze continuations drawn
//! from the held-out stream with distractor spans sampled elsewhere —
//! exercising the identical scoring code path.

use anyhow::Result;

use crate::data::batcher::Batcher;
use crate::runtime::ModelRuntime;
use crate::util::rng::Pcg;

/// Mean NLL over `n_batches` test batches (perplexity = exp).
pub fn perplexity(model: &ModelRuntime, test: &mut Batcher, n_batches: usize) -> Result<f64> {
    let mut total = 0.0f64;
    let n = n_batches.max(1);
    for _ in 0..n {
        total += model.eval_loss(&test.next_batch().tokens)? as f64;
    }
    Ok((total / n as f64).exp())
}

/// One multiple-choice question: `choices` full-length token rows that
/// share a context prefix and diverge at `span_start`; `answer` indexes the
/// gold row.
#[derive(Clone, Debug)]
pub struct McqQuestion {
    pub choices: Vec<Vec<i32>>,
    pub answer: usize,
    pub span_start: usize,
}

/// Build synthetic cloze questions from a held-out token stream.
///
/// Each question takes a window of `ctx` tokens; the final `span` tokens
/// are the gold continuation, and `n_choices - 1` distractor spans are cut
/// from random other stream positions.  `shots` solved examples (window +
/// gold continuation pairs from elsewhere in the stream) are prepended
/// inside the fixed ctx budget, mirroring the paper's 0-shot / 5-shot
/// protocol.
pub fn gen_cloze_questions(
    stream: &[u32],
    ctx: usize,
    n_questions: usize,
    n_choices: usize,
    span: usize,
    shots: usize,
    seed: u64,
) -> Vec<McqQuestion> {
    assert!(n_choices >= 2);
    let shot_len = (shots > 0).then(|| ctx / (shots + 1)).unwrap_or(0);
    let q_window = ctx - shots * shot_len;
    assert!(q_window > span, "ctx too small for span/shots");
    assert!(stream.len() > ctx + span + 1, "stream too short");
    let mut rng = Pcg::new(seed, 0x3c0e);
    let mut out = Vec::with_capacity(n_questions);
    for _ in 0..n_questions {
        // Few-shot prefix: solved windows (context + true continuation).
        let mut prefix: Vec<i32> = Vec::with_capacity(shots * shot_len);
        for _ in 0..shots {
            let s = rng.usize_below(stream.len() - shot_len);
            prefix.extend(stream[s..s + shot_len].iter().map(|&t| t as i32));
        }
        // Question window: context + gold span at the tail.
        let qs = rng.usize_below(stream.len() - q_window);
        let window: Vec<i32> = stream[qs..qs + q_window].iter().map(|&t| t as i32).collect();
        let span_start = ctx - span;

        let answer = rng.usize_below(n_choices);
        let mut choices = Vec::with_capacity(n_choices);
        for c in 0..n_choices {
            let mut row = prefix.clone();
            row.extend_from_slice(&window[..q_window - span]);
            if c == answer {
                row.extend_from_slice(&window[q_window - span..]);
            } else {
                // Distractor: a span from a random other position.
                let ds = rng.usize_below(stream.len() - span);
                row.extend(stream[ds..ds + span].iter().map(|&t| t as i32));
            }
            debug_assert_eq!(row.len(), ctx);
            choices.push(row);
        }
        out.push(McqQuestion { choices, answer, span_start });
    }
    out
}

/// Accuracy of likelihood-argmax over a question set.
///
/// Rows are packed into fwd batches of the artifact's batch size; each
/// choice is scored by the sum of next-token log-probabilities over its
/// span, and the argmax choice is compared with gold.
pub fn score_mcq(model: &ModelRuntime, questions: &[McqQuestion]) -> Result<f64> {
    if questions.is_empty() {
        return Ok(f64::NAN);
    }
    let ctx = model.ctx();
    let vocab = model.vocab();
    let batch = model.batch();

    // Flatten all rows, remembering (question, choice) per row.
    let mut rows: Vec<&[i32]> = Vec::new();
    for q in questions {
        for c in &q.choices {
            assert_eq!(c.len(), ctx, "choice rows must be ctx long");
            rows.push(c);
        }
    }
    let mut scores = vec![0.0f64; rows.len()];

    for chunk_start in (0..rows.len()).step_by(batch) {
        let chunk = &rows[chunk_start..(chunk_start + batch).min(rows.len())];
        let mut tokens = Vec::with_capacity(batch * ctx);
        for r in chunk {
            tokens.extend_from_slice(r);
        }
        // Pad the final partial batch by repeating the last row.
        for _ in chunk.len()..batch {
            tokens.extend_from_slice(chunk.last().unwrap());
        }
        let logits = model.forward(&tokens)?; // (batch, ctx, vocab) flat

        for (bi, row) in chunk.iter().enumerate() {
            let qi = (chunk_start + bi) / questions[0].choices.len();
            let span_start = questions[qi].span_start;
            let mut total = 0.0f64;
            // Token at position p is predicted by logits at p-1.
            for p in span_start..ctx {
                let lrow = &logits[(bi * ctx + p - 1) * vocab..(bi * ctx + p) * vocab];
                let target = row[p] as usize;
                total += log_softmax_at(lrow, target);
            }
            scores[chunk_start + bi] = total;
        }
    }

    let mut correct = 0usize;
    let mut idx = 0usize;
    for q in questions {
        let nc = q.choices.len();
        let qs = &scores[idx..idx + nc];
        let best = qs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if best == q.answer {
            correct += 1;
        }
        idx += nc;
    }
    Ok(correct as f64 / questions.len() as f64)
}

/// log softmax(row)[target] computed stably on the host.
fn log_softmax_at(row: &[f32], target: usize) -> f64 {
    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let logz: f64 = row.iter().map(|&v| ((v as f64) - max).exp()).sum::<f64>().ln() + max;
    row[target] as f64 - logz
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cloze_questions_shapes() {
        let stream: Vec<u32> = (0..5000).map(|i| 1 + i % 97).collect();
        let qs = gen_cloze_questions(&stream, 128, 10, 4, 16, 0, 0);
        assert_eq!(qs.len(), 10);
        for q in &qs {
            assert_eq!(q.choices.len(), 4);
            assert!(q.answer < 4);
            assert_eq!(q.span_start, 112);
            for c in &q.choices {
                assert_eq!(c.len(), 128);
            }
            // All choices share the context prefix.
            for c in &q.choices[1..] {
                assert_eq!(&c[..112], &q.choices[0][..112]);
            }
        }
    }

    #[test]
    fn cloze_five_shot_prefixes() {
        let stream: Vec<u32> = (0..9000).map(|i| 1 + i % 89).collect();
        let qs = gen_cloze_questions(&stream, 120, 4, 2, 8, 5, 3);
        for q in &qs {
            assert_eq!(q.choices[0].len(), 120);
            assert_eq!(q.span_start, 112);
        }
    }

    #[test]
    fn cloze_gold_span_is_true_continuation() {
        // The gold choice must be the stream's actual continuation: its
        // span must continue the arithmetic pattern of its context.
        let stream: Vec<u32> = (0..5000).map(|i| 1 + i % 97).collect();
        let qs = gen_cloze_questions(&stream, 64, 20, 4, 8, 0, 1);
        for q in &qs {
            let gold = &q.choices[q.answer];
            for p in q.span_start..gold.len() {
                let prev = gold[p - 1] as u32;
                let want = 1 + (prev % 97);
                assert_eq!(gold[p] as u32, want, "gold span must continue stream");
            }
        }
    }

    #[test]
    fn log_softmax_normalizes() {
        let row = [1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|t| log_softmax_at(&row, t).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
