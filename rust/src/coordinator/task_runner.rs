//! Synthetic-task training + accuracy evaluation (Appendix F protocol).
//!
//! Drives a model artifact on Selective Copying or Induction Heads batches
//! and measures exact-match accuracy (every answer position greedily
//! correct) on held-out examples — Table 5 / Figure 5 / Appendix F.2.

use anyhow::Result;

use crate::metrics::{Record, RunLogger};
use crate::runtime::ModelRuntime;
use crate::tasks::{answers_correct, Example};
use crate::util::rng::Pcg;

/// A generator of task batches (selective copy, induction heads, ...).
pub trait TaskSource {
    /// Flat (batch, ctx+1) i32 batch + per-example metadata.
    fn batch(&self, batch: usize, rng: &mut Pcg) -> (Vec<i32>, Vec<Example>);
    fn vocab(&self) -> usize;
    fn ctx(&self) -> usize;
}

impl TaskSource for crate::tasks::selective_copy::SelectiveCopyTask {
    fn batch(&self, batch: usize, rng: &mut Pcg) -> (Vec<i32>, Vec<Example>) {
        self.batch(batch, rng)
    }

    fn vocab(&self) -> usize {
        self.vocab()
    }

    fn ctx(&self) -> usize {
        self.ctx
    }
}

impl TaskSource for crate::tasks::induction::InductionTask {
    fn batch(&self, batch: usize, rng: &mut Pcg) -> (Vec<i32>, Vec<Example>) {
        self.batch(batch, rng)
    }

    fn vocab(&self) -> usize {
        self.vocab()
    }

    fn ctx(&self) -> usize {
        self.ctx
    }
}

/// Accuracy pair: the paper's Table-5 exact-match metric plus the
/// smoother per-answer-token accuracy (useful at reduced training budgets
/// where exact match over 16 positions is all-or-nothing).
#[derive(Clone, Copy, Debug, Default)]
pub struct Accuracy {
    /// Fraction of examples with EVERY answer position greedily correct.
    pub exact: f64,
    /// Fraction of answer positions greedily correct.
    pub token: f64,
}

/// Result of one task run.
#[derive(Clone, Debug, Default)]
pub struct TaskSummary {
    pub steps_run: u64,
    pub final_loss: f32,
    /// (step, accuracy) at every eval point — the Figure-5 learning curve.
    pub curve: Vec<(u64, Accuracy)>,
    pub final_accuracy: Accuracy,
}

/// Task runner configuration.
#[derive(Clone, Debug)]
pub struct TaskRunnerConfig {
    pub steps: u64,
    pub eval_every: u64,
    /// Held-out examples scored per evaluation.
    pub eval_examples: usize,
    pub echo_every: u64,
    pub seed: u64,
    /// Stop early once accuracy reaches this level (0 disables).
    pub stop_at_accuracy: f64,
}

impl Default for TaskRunnerConfig {
    fn default() -> Self {
        TaskRunnerConfig {
            steps: 400,
            eval_every: 50,
            eval_examples: 64,
            echo_every: 25,
            seed: 0,
            stop_at_accuracy: 0.0,
        }
    }
}

/// Train `model` on `task` batches and measure exact-match accuracy.
pub fn run_task(
    model: &mut ModelRuntime,
    task: &dyn TaskSource,
    cfg: &TaskRunnerConfig,
) -> Result<TaskSummary> {
    assert!(model.vocab() >= task.vocab(), "model vocab too small for task");
    assert_eq!(model.ctx(), task.ctx(), "model/task ctx mismatch");
    let mut train_rng = Pcg::new(cfg.seed, 0x7a5c);
    let mut logger = RunLogger::new(None, cfg.echo_every)?;
    let mut summary = TaskSummary::default();

    for _ in 0..cfg.steps {
        let (tokens, _) = task.batch(model.batch(), &mut train_rng);
        let stats = model.train_step(&tokens)?;
        summary.steps_run += 1;
        summary.final_loss = stats.loss;
        logger.log_step(stats.step, stats.loss as f64, Record::new())?;
        if cfg.eval_every > 0 && stats.step % cfg.eval_every == 0 {
            let acc = eval_accuracy(model, task, cfg.eval_examples, cfg.seed ^ 0xe7a1)?;
            summary.curve.push((stats.step, acc));
            if cfg.echo_every > 0 {
                eprintln!(
                    "step {:>6}  exact {:.2}%  token {:.2}%",
                    stats.step,
                    acc.exact * 100.0,
                    acc.token * 100.0
                );
            }
            if cfg.stop_at_accuracy > 0.0 && acc.exact >= cfg.stop_at_accuracy {
                break;
            }
        }
    }
    summary.final_accuracy =
        eval_accuracy(model, task, cfg.eval_examples, cfg.seed ^ 0xf17a1)?;
    Ok(summary)
}

/// Accuracy over `n` fresh held-out examples: exact match (the paper's
/// Table-5 metric) and per-answer-token accuracy.
pub fn eval_accuracy(
    model: &ModelRuntime,
    task: &dyn TaskSource,
    n: usize,
    seed: u64,
) -> Result<Accuracy> {
    let batch = model.batch();
    let ctx = model.ctx();
    let vocab = model.vocab();
    let mut rng = Pcg::new(seed, 0xacc);
    let (mut exact, mut tok_hit, mut tok_total) = (0usize, 0usize, 0usize);
    let mut seen = 0usize;
    while seen < n {
        let (tokens, examples) = task.batch(batch, &mut rng);
        // fwd consumes (batch, ctx): strip the final target token and the
        // loss-mask signs from each row.
        let mut inputs = Vec::with_capacity(batch * ctx);
        for b in 0..batch {
            let row = &tokens[b * (ctx + 1)..(b + 1) * (ctx + 1)];
            inputs.extend(row[..ctx].iter().map(|&t| t.abs()));
        }
        let logits = model.forward(&inputs)?; // (batch, ctx, vocab) flat
        for (b, ex) in examples.iter().enumerate().take(n - seen) {
            let lrow = &logits[b * ctx * vocab..(b + 1) * ctx * vocab];
            let hit = answers_correct(ex, lrow, vocab);
            tok_hit += hit;
            tok_total += ex.answer_positions.len();
            if hit == ex.answer_positions.len() {
                exact += 1;
            }
        }
        seen += examples.len().min(n - seen);
    }
    Ok(Accuracy {
        exact: exact as f64 / n as f64,
        token: tok_hit as f64 / tok_total.max(1) as f64,
    })
}
