//! Config substrate: a TOML-subset parser + typed accessors.
//!
//! No serde/toml crates in this environment, so the launcher's run configs
//! are parsed by this module.  Supported grammar (a practical TOML subset):
//!
//! ```toml
//! # comment
//! key = "string"            # strings (double-quoted, \" \\ \n escapes)
//! steps = 500               # integers
//! lr = 3e-4                 # floats
//! local = true              # booleans
//! ctxs = [64, 128, 256]     # homogeneous arrays of the above
//! [section]                 # tables (one level)
//! key = 1
//! [section.sub]             # nested tables via dotted headers
//! ```

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Parsed config: flat map of dotted keys ("section.sub.key") -> Value.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Config {
    pub fn parse(text: &str) -> Result<Self, ParseError> {
        let mut values = BTreeMap::new();
        let mut prefix = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                let inner = line
                    .strip_prefix('[')
                    .and_then(|s| s.strip_suffix(']'))
                    .ok_or_else(|| err(ln, "malformed table header"))?
                    .trim();
                if inner.is_empty() {
                    return Err(err(ln, "empty table name"));
                }
                prefix = format!("{inner}.");
                continue;
            }
            let eq = line.find('=').ok_or_else(|| err(ln, "expected key = value"))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err(ln, "empty key"));
            }
            let val = parse_value(line[eq + 1..].trim()).map_err(|m| err(ln, &m))?;
            values.insert(format!("{prefix}{key}"), val);
        }
        Ok(Config { values })
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::parse(&text)?)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn ints(&self, key: &str) -> Option<Vec<i64>> {
        self.get(key)?.as_array()?.iter().map(Value::as_int).collect()
    }

    pub fn set(&mut self, key: &str, val: Value) {
        self.values.insert(key.to_string(), val);
    }

    /// Keys (sorted, dotted).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(String::as_str)
    }

    /// Overlay `other` onto self (other wins). Used for CLI overrides.
    pub fn merge(&mut self, other: &Config) {
        for (k, v) in &other.values {
            self.values.insert(k.clone(), v.clone());
        }
    }
}

fn err(ln: usize, msg: &str) -> ParseError {
    ParseError { line: ln + 1, msg: msg.to_string() }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            '\\' if in_str => escaped = !escaped,
            '"' if !escaped => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => escaped = false,
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part)?);
        }
        return Ok(Value::Array(items));
    }
    if s.starts_with('"') {
        return parse_string(s);
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s}"))
}

fn parse_string(s: &str) -> Result<Value, String> {
    let inner = s
        .strip_prefix('"')
        .and_then(|x| x.strip_suffix('"'))
        .ok_or("unterminated string")?;
    let mut out = String::new();
    let mut chars = inner.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                other => return Err(format!("bad escape: \\{other:?}")),
            }
        } else {
            out.push(c);
        }
    }
    Ok(Value::Str(out))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        let c = Config::parse("a = 1\nb = 2.5\nc = \"hi\"\nd = true\n").unwrap();
        assert_eq!(c.int_or("a", 0), 1);
        assert_eq!(c.float_or("b", 0.0), 2.5);
        assert_eq!(c.str_or("c", ""), "hi");
        assert!(c.bool_or("d", false));
    }

    #[test]
    fn parse_sections_and_arrays() {
        let text = "top = 1\n[run]\nsteps = 100\nctxs = [64, 128, 256]\n[run.adam]\nlr = 3e-4\n";
        let c = Config::parse(text).unwrap();
        assert_eq!(c.int_or("top", 0), 1);
        assert_eq!(c.int_or("run.steps", 0), 100);
        assert_eq!(c.ints("run.ctxs").unwrap(), vec![64, 128, 256]);
        assert!((c.float_or("run.adam.lr", 0.0) - 3e-4).abs() < 1e-12);
    }

    #[test]
    fn comments_and_strings_with_hash() {
        let c = Config::parse("a = \"x # y\" # trailing\n# whole line\nb = 2\n").unwrap();
        assert_eq!(c.str_or("a", ""), "x # y");
        assert_eq!(c.int_or("b", 0), 2);
    }

    #[test]
    fn escapes() {
        let c = Config::parse(r#"a = "l1\nl2\t\"q\"""#).unwrap();
        assert_eq!(c.str_or("a", ""), "l1\nl2\t\"q\"");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = Config::parse("ok = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn merge_overrides() {
        let mut a = Config::parse("x = 1\ny = 2").unwrap();
        let b = Config::parse("y = 3\nz = 4").unwrap();
        a.merge(&b);
        assert_eq!(a.int_or("x", 0), 1);
        assert_eq!(a.int_or("y", 0), 3);
        assert_eq!(a.int_or("z", 0), 4);
    }

    #[test]
    fn int_fallback_to_float() {
        let c = Config::parse("n = 3").unwrap();
        assert_eq!(c.float_or("n", 0.0), 3.0);
    }

    #[test]
    fn nested_arrays() {
        let c = Config::parse("m = [[1, 2], [3]]").unwrap();
        let outer = c.get("m").unwrap().as_array().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[0].as_array().unwrap().len(), 2);
    }
}
