//! psf — PolySketchFormer launcher.
//!
//! Subcommands:
//!   list                       discover artifact bundles
//!   train                      train a model artifact on a synthetic corpus
//!   train-native               train the native model (no artifacts, backprop in-crate)
//!   dp-train                   data-parallel training (native backprop, exact allreduce)
//!   task                       train + evaluate a synthetic task artifact
//!   eval                       perplexity + downstream MCQ of a trained run
//!   attn                       run one attention micro-artifact (sanity)
//!   generate                   autoregressive decoding (native model path)
//!   serve                      HTTP serving gateway (single- or multi-process)
//!   runner                     [hidden] model-runner process (spawned by serve)
//!
//! Artifact-backed subcommands execute AOT-compiled HLO through the PJRT
//! CPU client; Python is never invoked (`make artifacts` must have run
//! once).  `train-native`, `dp-train`, `generate`, and `serve` run
//! entirely on the native kernels — no artifacts — and share one
//! checkpoint format, so natively trained weights are directly servable.
//! `psf serve --runners N` spawns N `psf runner` worker processes behind
//! the gateway (data-parallel replicas, or head shards with `--tp`).

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};
use polysketchformer::attn::Mechanism;
use polysketchformer::cli::{Args, CliError};
use polysketchformer::infer::{self, LmConfig, NativeLm, SamplePolicy, Scheduler, SchedulerConfig};
use polysketchformer::coordinator::{
    self, DataParallel, TaskRunnerConfig, Trainer, TrainerConfig,
};
use polysketchformer::data::{self, batcher::Batcher, corpus::Flavor};
use polysketchformer::metrics::RunLogger;
use polysketchformer::runtime::{self, LoadOpts};
use polysketchformer::serve::{Gateway, GatewayConfig, WorkerConfig};
use polysketchformer::shard;
use polysketchformer::tasks::{induction::InductionTask, selective_copy::SelectiveCopyTask};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    // PSF_TRACE=<path> turns span tracing on for any subcommand; the
    // serve/runner paths flush on their drain paths, everything else
    // flushes via the catch-all below.
    polysketchformer::obs::init_from_env();
    let Some(cmd) = argv.first().map(String::as_str) else {
        eprintln!("{}", top_usage());
        return Ok(());
    };
    let rest = &argv[1..];
    let result = match cmd {
        "list" => cmd_list(),
        "run" => cmd_run(rest),
        "train" => cmd_train(rest),
        "train-native" => cmd_train_native(rest),
        "dp-train" => cmd_dp_train(rest),
        "task" => cmd_task(rest),
        "eval" => cmd_eval(rest),
        "attn" => cmd_attn(rest),
        "generate" => cmd_generate(rest),
        "serve" => cmd_serve(rest),
        "trace-report" => cmd_trace_report(rest),
        "incident-report" => cmd_incident_report(rest),
        // Hidden: the worker-process body `psf serve --runners N` spawns.
        // Deliberately absent from `top_usage` — never invoked by hand.
        "runner" => cmd_runner(rest),
        "--help" | "-h" | "help" => {
            eprintln!("{}", top_usage());
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try --help)"),
    };
    // Catch-all flush for PSF_TRACE on subcommands without their own
    // drain path; serve and runner flush themselves (and print there).
    if !matches!(cmd, "serve" | "runner") {
        match polysketchformer::obs::flush() {
            Ok(Some(path)) => eprintln!("psf: trace written to {}", path.display()),
            Ok(None) => {}
            Err(e) => eprintln!("psf: trace flush failed: {e}"),
        }
    }
    result
}

// ---------------------------------------------------------- trace-report

/// Summarize a Chrome trace-event file written by `--trace`/`PSF_TRACE`:
/// top spans by self time, cross-process trace-id stitching, and the
/// kernel/pool phase breakdown.
fn cmd_trace_report(argv: &[String]) -> Result<()> {
    let spec = Args::new("psf trace-report", "summarize a trace.json written by --trace")
        .req("trace", "path to the trace file")
        .opt("top", "15", "rows in the top-spans-by-self-time table");
    let p = parse(spec, argv)?;
    let path = p.str("trace");
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading {path}: {e}"))?;
    let tf = polysketchformer::obs::trace::parse(&text)
        .map_err(|e| anyhow!("parsing {path}: {e}"))?;
    print!("{}", polysketchformer::obs::trace::report(&tf, p.usize("top")?));
    Ok(())
}

// ------------------------------------------------------- incident-report

/// Render an `incident.json` written by `--incident`/`PSF_INCIDENT`
/// (panic, sentinel trip, runner death, or shutdown signal) as a
/// human-readable postmortem: fault attribution, build config, the
/// flight-recorder window, and in-flight requests at dump time.
fn cmd_incident_report(argv: &[String]) -> Result<()> {
    let spec = Args::new("psf incident-report", "render an incident.json dump")
        .req("incident", "path to the incident file");
    let p = parse(spec, argv)?;
    let path = p.str("incident");
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading {path}: {e}"))?;
    let report = polysketchformer::obs::incident::report(&text)
        .map_err(|e| anyhow!("parsing {path}: {e}"))?;
    print!("{report}");
    Ok(())
}

fn top_usage() -> String {
    "psf — PolySketchFormer coordinator (ICML 2024 reproduction)\n\n\
     subcommands:\n\
       list        discover artifact bundles in ./artifacts\n\
       run         execute a TOML run config (see configs/)\n\
       train       train a model artifact on a synthetic corpus\n\
       train-native  train the native model in-crate (tasks or byte LM)\n\
       dp-train    simulated data-parallel training (grad allreduce)\n\
       task        train + evaluate a synthetic task (copy | induction)\n\
       eval        perplexity + downstream MCQ accuracy\n\
       attn        run one attention micro-artifact\n\
       generate    autoregressive decoding on the native model path\n\
       serve       HTTP serving gateway (concurrent workers + prompt cache)\n\
       trace-report  summarize a trace.json written by `serve --trace` / PSF_TRACE\n\
       incident-report  render an incident.json written by `--incident` / PSF_INCIDENT\n\n\
     run `psf <subcommand> --help` for flags."
        .to_string()
}

fn parse(spec: Args, argv: &[String]) -> Result<polysketchformer::cli::Parsed> {
    match spec.parse(argv) {
        Ok(p) => Ok(p),
        Err(CliError::Help) => {
            eprintln!("{}", spec.usage());
            std::process::exit(0);
        }
        Err(e) => Err(anyhow!("{e}")),
    }
}

// ------------------------------------------------------------------ list

fn cmd_list() -> Result<()> {
    let dir = runtime::artifacts_dir();
    let mans = runtime::discover(&dir)?;
    if mans.is_empty() {
        bail!("no manifests in {} — run `make artifacts`", dir.display());
    }
    println!("{:<55} {:>8} {:>10} {:>6} {:>6}", "name", "kind", "params", "ctx", "batch");
    for (name, m) in &mans {
        println!(
            "{:<55} {:>8} {:>10} {:>6} {:>6}",
            name,
            m.kind,
            m.nparams,
            m.cfg_str("ctx").or(m.cfg_str("n")).unwrap_or("-"),
            m.batch,
        );
    }
    Ok(())
}

// ------------------------------------------------------------------- run

/// Execute a declarative TOML run config (the launcher path a deployment
/// would drive; configs/ has annotated samples).  Keys map 1:1 onto the
/// train / dp-train / task subcommand flags.
fn cmd_run(argv: &[String]) -> Result<()> {
    let spec = Args::new("psf run", "execute a TOML run config")
        .req("config", "path to run config (see configs/)");
    let p = parse(spec, argv)?;
    let cfg = polysketchformer::config::Config::load(std::path::Path::new(p.str("config")))?;

    let mode = cfg.str_or("mode", "train").to_string();
    let model = cfg
        .get("model")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("config missing `model`"))?
        .to_string();
    let steps = cfg.int_or("steps", 200).to_string();
    let seed = cfg.int_or("seed", 0).to_string();

    let mut argv: Vec<String> = vec![
        "--model".into(),
        model,
        "--steps".into(),
        steps.clone(),
        "--seed".into(),
        seed.clone(),
    ];
    match mode.as_str() {
        "train" => {
            argv.extend([
                "--corpus".into(),
                cfg.str_or("data.corpus", "books").into(),
                "--corpus-bytes".into(),
                cfg.int_or("data.bytes", 2_000_000).to_string(),
                "--eval-every".into(),
                cfg.int_or("eval.every", 50).to_string(),
                "--eval-batches".into(),
                cfg.int_or("eval.batches", 4).to_string(),
                "--ckpt-every".into(),
                cfg.int_or("log.ckpt_every", 0).to_string(),
                "--run-dir".into(),
                cfg.str_or("log.run_dir", "").into(),
            ]);
            cmd_train(&argv)?;
            // Optional closing MCQ eval.
            if cfg.int_or("eval.questions", 0) > 0 {
                let eval_argv: Vec<String> = vec![
                    "--model".into(),
                    cfg.get("model").unwrap().as_str().unwrap().into(),
                    "--corpus".into(),
                    cfg.str_or("data.corpus", "books").into(),
                    "--corpus-bytes".into(),
                    cfg.int_or("data.bytes", 2_000_000).to_string(),
                    "--questions".into(),
                    cfg.int_or("eval.questions", 100).to_string(),
                    "--choices".into(),
                    cfg.int_or("eval.choices", 4).to_string(),
                    "--span".into(),
                    cfg.int_or("eval.span", 16).to_string(),
                    "--shots".into(),
                    cfg.int_or("eval.shots", 0).to_string(),
                ];
                cmd_eval(&eval_argv)?;
            }
            Ok(())
        }
        "dp-train" => {
            // Native path: no artifact — the config's `mech` key (not
            // `model`) picks the attention mechanism.
            let dp_argv: Vec<String> = vec![
                "--mech".into(),
                cfg.str_or("mech", "psk4_r8_b16_local").into(),
                "--steps".into(),
                steps,
                "--seed".into(),
                seed,
                "--workers".into(),
                cfg.int_or("dp.workers", 4).to_string(),
                "--accum".into(),
                cfg.int_or("dp.accum", 1).to_string(),
                "--corpus".into(),
                cfg.str_or("data.corpus", "books").into(),
                "--corpus-bytes".into(),
                cfg.int_or("data.bytes", 4_000_000).to_string(),
            ];
            cmd_dp_train(&dp_argv)
        }
        "task" => {
            argv.extend([
                "--task".into(),
                cfg.str_or("task", "").into(),
                "--eval-every".into(),
                cfg.int_or("eval.every", 50).to_string(),
                "--eval-examples".into(),
                cfg.int_or("eval.examples", 64).to_string(),
                "--stop-at".into(),
                cfg.float_or("eval.stop_at_percent", 0.0).to_string(),
            ]);
            cmd_task(&argv)
        }
        other => bail!("config mode `{other}` (want train | dp-train | task)"),
    }
}

// ----------------------------------------------------------------- train

fn cmd_train(argv: &[String]) -> Result<()> {
    let spec = Args::new("psf train", "train a model artifact on a synthetic corpus")
        .req("model", "artifact name (see `psf list`)")
        .opt("steps", "200", "training steps")
        .opt("corpus", "books", "books | wiki | web")
        .opt("corpus-bytes", "2000000", "synthetic corpus size in bytes")
        .opt("eval-every", "50", "eval cadence (0 = never)")
        .opt("eval-batches", "4", "batches per eval")
        .opt("ckpt-every", "0", "checkpoint cadence (0 = never)")
        .opt("run-dir", "", "log/checkpoint directory (empty = none)")
        .opt("seed", "0", "data seed");
    let p = parse(spec, argv)?;

    let mut model = runtime::load_model(p.str("model"), LoadOpts::default())?;
    let flavor = Flavor::parse(p.str("corpus"))
        .ok_or_else(|| anyhow!("bad corpus {}", p.str("corpus")))?;
    let seed = p.u64("seed")?;
    let ds = data::load_corpus_tokens(
        flavor,
        p.usize("corpus-bytes")?,
        model.vocab(),
        seed,
        None,
    )?;
    let train = Batcher::new(&ds.train, model.batch(), model.ctx() + 1, seed);
    let test = Batcher::new(&ds.test, model.batch(), model.ctx() + 1, seed);

    let cfg = TrainerConfig {
        steps: p.u64("steps")?,
        eval_every: p.u64("eval-every")?,
        eval_batches: p.usize("eval-batches")?,
        ckpt_every: p.u64("ckpt-every")?,
        echo_every: 10,
        run_dir: non_empty(p.str("run-dir")).map(PathBuf::from),
        nan_guard: true,
    };
    let summary = Trainer::new(&mut model, train, Some(test), cfg).run()?;
    println!(
        "done: {} steps, final loss {:.4} (ema {:.4}), test ppl {:.2}, {:.2} steps/s, {:.0} tok/s",
        summary.steps_run,
        summary.final_loss,
        summary.final_loss_ema,
        summary.final_perplexity(),
        summary.steps_per_sec(),
        summary.tokens_per_sec(),
    );
    Ok(())
}

// ---------------------------------------------------------- train-native

/// Native training: hand-written backprop through the kernel core — no
/// artifacts, no PJRT.  Trains the synthetic tasks (induction heads,
/// selective copying) or a byte-level LM corpus, checkpoints `Params` +
/// optimizer state for exact `--resume`, and produces weights `psf
/// generate --checkpoint` / `psf serve --checkpoint` load directly.
fn cmd_train_native(argv: &[String]) -> Result<()> {
    let spec = Args::new("psf train-native", "train the native model (in-crate backprop)")
        .opt("task", "induction", "induction | copy | lm")
        .opt("ctx", "48", "context length (task sequence length)")
        .opt("mech", "psk4_r8_b16_local",
             "mechanism label (softmax | flash_b<B> | poly<P> | psk<P>_r<R>_b<B>[_local] | performer<M>_b<B>)")
        .opt("d-model", "64", "model width")
        .opt("layers", "2", "transformer layers")
        .opt("heads", "4", "attention heads")
        .opt("steps", "300", "training steps")
        .opt("batch", "16", "sequences per step")
        .opt("lr", "0.003", "peak learning rate")
        .opt("warmup", "20", "linear warmup steps")
        .opt("weight-decay", "0.01", "decoupled AdamW weight decay")
        .opt("clip", "1.0", "global-norm gradient clip (0 = off)")
        .opt("eval-every", "50", "held-out eval cadence (0 = end only)")
        .opt("eval-examples", "64", "examples per eval")
        .opt("stop-at", "0", "early-stop accuracy in percent (0 = off)")
        .opt("ckpt", "", "checkpoint path (empty = no checkpointing)")
        .opt("ckpt-every", "0", "checkpoint cadence in steps (0 = end only)")
        .switch("resume", "resume params + optimizer from --ckpt if it exists")
        .opt("corpus", "books", "books | wiki | web (task = lm)")
        .opt("corpus-bytes", "2000000", "synthetic corpus size in bytes (task = lm)")
        .opt("log", "", "JSONL metrics path (empty = none)")
        .opt("threads", "0", "compute threads (0 = PSF_THREADS env, else all cores)")
        .opt("seed", "0", "weight + data seed");
    let p = parse(spec, argv)?;
    apply_threads(&p)?;

    use polysketchformer::train::{OptimConfig, TrainConfig, TrainSource, Trainer};

    let mech = Mechanism::parse(p.str("mech")).map_err(|e| anyhow!("{e}"))?;
    let ctx = p.usize("ctx")?;
    let steps = p.u64("steps")?;
    let seed = p.u64("seed")?;

    // Data source + vocabulary.
    let (source, vocab) = match p.str("task") {
        "induction" => {
            let task = InductionTask::standard(ctx);
            (TrainSource::Induction(task), task.vocab())
        }
        "copy" => {
            let task = SelectiveCopyTask::standard(ctx);
            (TrainSource::Copy(task), task.vocab())
        }
        "lm" => {
            let flavor = Flavor::parse(p.str("corpus"))
                .ok_or_else(|| anyhow!("bad corpus {}", p.str("corpus")))?;
            // Byte-level tokens (id 0 = BOS, ids 1..=256 = bytes) — the
            // *same* encoding `psf generate`/`psf serve` use for prompts
            // (`infer::encode_prompt`), so trained checkpoints decode
            // real text.  No BPE: that path needs vocab > 257 and would
            // produce ids the serving tokenizer cannot reproduce.
            let vocab = 257usize;
            let gen = data::corpus::CorpusGen::new(flavor, seed);
            let text = gen.generate(p.usize("corpus-bytes")?, seed ^ 0x9e37);
            let stream: Vec<u32> = text.bytes().map(|b| b as u32 + 1).collect();
            let (train_s, test_s) = data::batcher::split_stream(&stream, 0.1);
            let batch = p.usize("batch")?;
            let train = Batcher::new(train_s, batch, ctx + 1, seed);
            // Held-out eval split (skipped when the test split is too
            // short for even one batch — evals then read a clone of the
            // training stream).
            let eval = (test_s.len() / (ctx + 1) >= batch)
                .then(|| Batcher::new(test_s, batch, ctx + 1, seed ^ 1));
            (TrainSource::Corpus { train, eval }, vocab)
        }
        other => bail!("unknown task `{other}` (want induction | copy | lm)"),
    };

    // Model: resume from the checkpoint when asked (and present), else
    // fresh deterministic init.
    let ckpt_path = non_empty(p.str("ckpt")).map(PathBuf::from);
    let resume_ck = match (&ckpt_path, p.flag("resume")) {
        (Some(path), true) if path.exists() => Some(
            polysketchformer::checkpoint::Checkpoint::load(path)
                .map_err(|e| anyhow!("{e}"))?,
        ),
        (None, true) => bail!("--resume needs --ckpt"),
        _ => None,
    };
    let mut model = match &resume_ck {
        Some(ck) => {
            let m = NativeLm::from_checkpoint(ck)?;
            println!(
                "resuming from {} (step {}, mech {})",
                ckpt_path.as_ref().unwrap().display(),
                ck.step,
                m.mech.label()
            );
            m
        }
        None => {
            let cfg = LmConfig {
                vocab,
                d_model: p.usize("d-model")?,
                layers: p.usize("layers")?,
                heads: p.usize("heads")?,
                seed,
                ..LmConfig::default()
            };
            if cfg.heads == 0
                || cfg.layers == 0
                || cfg.d_model % cfg.heads != 0
                || (cfg.d_model / cfg.heads) % 2 != 0
            {
                bail!(
                    "--d-model {} must split into --heads {} (>= 1) with an even head_dim",
                    cfg.d_model,
                    cfg.heads
                );
            }
            NativeLm::new(cfg, mech.clone())
        }
    };
    println!(
        "train-native: {} on mech {} ({} params, d_model {} x {} layers, ctx {ctx})",
        p.str("task"),
        model.mech.label(),
        model.params().num_params(),
        model.cfg.d_model,
        model.cfg.layers,
    );

    let tcfg = TrainConfig {
        steps,
        batch: p.usize("batch")?,
        optim: OptimConfig {
            lr: p.f64("lr")? as f32,
            warmup: p.u64("warmup")?,
            total_steps: steps,
            weight_decay: p.f64("weight-decay")? as f32,
            clip: p.f64("clip")? as f32,
            ..OptimConfig::default()
        },
        seed,
        eval_every: p.u64("eval-every")?,
        eval_examples: p.usize("eval-examples")?,
        stop_at_accuracy: p.f64("stop-at")? / 100.0,
        echo_every: 10,
        log_path: non_empty(p.str("log")).map(PathBuf::from),
        ckpt_path: ckpt_path.clone(),
        ckpt_every: p.u64("ckpt-every")?,
    };
    let mut trainer = Trainer::new(&mut model, source, tcfg);
    if let Some(ck) = &resume_ck {
        trainer.resume_from(ck)?;
    }
    let summary = trainer.run()?;
    // One stable, machine-parsable closing line (the CI train-smoke job
    // reads it).
    println!(
        "train-native final: steps={} initial_loss={:.4} final_loss={:.4} accuracy={:.4} \
         tokens={} wall={:.1}s",
        summary.steps_run,
        summary.initial_loss,
        summary.final_loss,
        summary.final_accuracy,
        summary.tokens_seen,
        summary.wall_secs,
    );
    if let Some(path) = &ckpt_path {
        println!("checkpoint: {}", path.display());
    }
    Ok(())
}

// -------------------------------------------------------------- dp-train

/// Simulated synchronous data-parallel training over the **native**
/// training subsystem: W workers on disjoint corpus shards, microbatch
/// accumulation, exact pairwise-tree allreduce, one optimizer update per
/// global step.  No artifacts, no PJRT — the same backprop `psf
/// train-native` uses, so W = accum = 1 reproduces it bitwise.
fn cmd_dp_train(argv: &[String]) -> Result<()> {
    let spec = Args::new(
        "psf dp-train",
        "data-parallel training on the native model (exact allreduce math)",
    )
    .opt("mech", "psk4_r8_b16_local",
         "mechanism label (softmax | flash_b<B> | poly<P> | psk<P>_r<R>_b<B>[_local] | performer<M>_b<B>)")
    .opt("workers", "4", "simulated data-parallel workers")
    .opt("accum", "1", "microbatches accumulated per worker per step")
    .opt("steps", "50", "global steps")
    .opt("ctx", "64", "context length")
    .opt("batch", "8", "sequences per microbatch per worker")
    .opt("d-model", "64", "model width")
    .opt("layers", "2", "transformer layers")
    .opt("heads", "4", "attention heads")
    .opt("lr", "0.003", "peak learning rate")
    .opt("warmup", "20", "linear warmup steps")
    .opt("corpus", "books", "books | wiki | web")
    .opt("corpus-bytes", "4000000", "synthetic corpus size in bytes")
    .opt("log", "", "JSONL metrics path (empty = none)")
    .opt("threads", "0", "compute threads (0 = PSF_THREADS env, else all cores)")
    .opt("seed", "0", "weight + data seed");
    let p = parse(spec, argv)?;
    apply_threads(&p)?;

    use polysketchformer::train::OptimConfig;

    let mech = Mechanism::parse(p.str("mech")).map_err(|e| anyhow!("{e}"))?;
    let ctx = p.usize("ctx")?;
    let steps = p.u64("steps")?;
    let seed = p.u64("seed")?;
    let flavor = Flavor::parse(p.str("corpus"))
        .ok_or_else(|| anyhow!("bad corpus {}", p.str("corpus")))?;

    // Byte-level stream, the encoding `psf serve`/`generate` decode
    // (id 0 = BOS/pad, ids 1..=256 = bytes).
    let gen = data::corpus::CorpusGen::new(flavor, seed);
    let text = gen.generate(p.usize("corpus-bytes")?, seed ^ 0x9e37);
    let stream: Vec<u32> = text.bytes().map(|b| b as u32 + 1).collect();

    let mut cfg = native_lm_config(&p)?;
    cfg.vocab = 257;
    let mut model = NativeLm::new(cfg, mech);
    println!(
        "dp-train: mech {} ({} params, d_model {} x {} layers, ctx {ctx})",
        model.mech.label(),
        model.params().num_params(),
        model.cfg.d_model,
        model.cfg.layers,
    );

    let optim = OptimConfig {
        lr: p.f64("lr")? as f32,
        warmup: p.u64("warmup")?,
        total_steps: steps,
        ..OptimConfig::default()
    };
    let mut dp = DataParallel::from_stream(
        &mut model,
        &stream,
        p.usize("workers")?,
        p.usize("batch")?,
        ctx + 1,
        p.usize("accum")?,
        seed,
        optim,
    );
    println!(
        "dp-train: {} workers x {} accum = {} tokens/step",
        dp.world_size(),
        dp.accum,
        dp.tokens_per_step(),
    );
    let mut logger = RunLogger::new(non_empty(p.str("log")).map(std::path::Path::new), 5)?;
    let (last, _) = dp.run(steps, &mut logger)?;
    // One stable, machine-parsable closing line (mirrors train-native's).
    println!(
        "dp-train final: step={} loss={:.4} grad_norm={:.4} lr={:.5}",
        last.step, last.loss, last.grad_norm, last.lr,
    );
    Ok(())
}

// ------------------------------------------------------------------ task

fn cmd_task(argv: &[String]) -> Result<()> {
    let spec = Args::new("psf task", "train + evaluate a synthetic task artifact")
        .req("model", "task artifact name (copy_* | induction_*)")
        .opt("task", "", "copy | induction (inferred from model name if empty)")
        .opt("steps", "400", "training steps")
        .opt("eval-every", "50", "accuracy eval cadence")
        .opt("eval-examples", "64", "held-out examples per eval")
        .opt("stop-at", "0", "early-stop accuracy in percent (0 = off)")
        .opt("seed", "0", "seed");
    let p = parse(spec, argv)?;

    let name = p.str("model");
    let mut model = runtime::load_model(name, LoadOpts::default())?;
    let kind = match non_empty(p.str("task")) {
        Some(t) => t.to_string(),
        None if name.starts_with("copy") => "copy".into(),
        None if name.starts_with("induction") => "induction".into(),
        None => bail!("cannot infer task from `{name}`; pass --task"),
    };
    let cfg = TaskRunnerConfig {
        steps: p.u64("steps")?,
        eval_every: p.u64("eval-every")?,
        eval_examples: p.usize("eval-examples")?,
        echo_every: 25,
        seed: p.u64("seed")?,
        stop_at_accuracy: p.f64("stop-at")? / 100.0,
    };
    let summary = match kind.as_str() {
        "copy" => {
            let task = SelectiveCopyTask::standard(model.ctx());
            coordinator::run_task(&mut model, &task, &cfg)?
        }
        "induction" => {
            let task = InductionTask::standard(model.ctx());
            coordinator::run_task(&mut model, &task, &cfg)?
        }
        other => bail!("unknown task {other}"),
    };
    println!(
        "done: {} steps, final loss {:.4}, exact {:.2}% / token {:.2}%",
        summary.steps_run,
        summary.final_loss,
        summary.final_accuracy.exact * 100.0,
        summary.final_accuracy.token * 100.0,
    );
    Ok(())
}

// ------------------------------------------------------------------ eval

fn cmd_eval(argv: &[String]) -> Result<()> {
    let spec = Args::new("psf eval", "perplexity + downstream MCQ accuracy")
        .req("model", "artifact name")
        .opt("corpus", "web", "books | wiki | web (held-out stream)")
        .opt("corpus-bytes", "2000000", "synthetic corpus size")
        .opt("ppl-batches", "8", "batches for perplexity")
        .opt("questions", "100", "MCQ questions")
        .opt("choices", "4", "choices per question")
        .opt("span", "16", "continuation span tokens")
        .opt("shots", "0", "few-shot examples per question")
        .opt("checkpoint", "", "restore state from checkpoint file")
        .opt("seed", "0", "seed");
    let p = parse(spec, argv)?;

    let mut model = runtime::load_model(
        p.str("model"),
        LoadOpts { train: false, evalloss: true, fwd: true, grads: false },
    )?;
    if let Some(ck) = non_empty(p.str("checkpoint")) {
        let ckpt = polysketchformer::checkpoint::Checkpoint::load(std::path::Path::new(ck))?;
        let state = ckpt
            .get("state")
            .ok_or_else(|| anyhow!("checkpoint has no state section"))?;
        model.set_state(state)?;
        println!("restored checkpoint at step {}", ckpt.step);
    }
    let flavor = Flavor::parse(p.str("corpus"))
        .ok_or_else(|| anyhow!("bad corpus {}", p.str("corpus")))?;
    let seed = p.u64("seed")?;
    let ds = data::load_corpus_tokens(
        flavor,
        p.usize("corpus-bytes")?,
        model.vocab(),
        seed,
        None,
    )?;
    let mut test = Batcher::new(&ds.test, model.batch(), model.ctx() + 1, seed);
    let ppl = coordinator::perplexity(&model, &mut test, p.usize("ppl-batches")?)?;
    println!("perplexity: {ppl:.3}");

    let shots = p.usize("shots")?;
    let qs = coordinator::gen_cloze_questions(
        &ds.test,
        model.ctx(),
        p.usize("questions")?,
        p.usize("choices")?,
        p.usize("span")?,
        shots,
        seed,
    );
    let acc = coordinator::score_mcq(&model, &qs)?;
    println!("mcq accuracy ({shots}-shot, {} questions): {:.1}%", qs.len(), acc * 100.0);
    Ok(())
}

// ------------------------------------------------------------------ attn

fn cmd_attn(argv: &[String]) -> Result<()> {
    let spec = Args::new("psf attn", "run one attention micro-artifact")
        .req("name", "attn artifact name (see `psf list`)")
        .opt("iters", "3", "executions to time")
        .opt("seed", "0", "input seed");
    let p = parse(spec, argv)?;

    let micro = runtime::load_attn(p.str("name"))?;
    let n = micro.numel();
    let mut rng = polysketchformer::Pcg::seeded(p.u64("seed")?);
    let q: Vec<f32> = (0..n).map(|_| rng.gaussian() * 0.5).collect();
    let k: Vec<f32> = (0..n).map(|_| rng.gaussian() * 0.5).collect();
    let v: Vec<f32> = (0..n).map(|_| rng.gaussian() * 0.5).collect();

    let iters = p.usize("iters")?;
    let mut out = Vec::new();
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        out = micro.run(&q, &k, &v)?;
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let finite = out.iter().all(|x| x.is_finite());
    println!(
        "{}: {} elements, {:.3} ms/exec, finite={}",
        micro.manifest.name,
        out.len(),
        per * 1e3,
        finite,
    );
    if !finite {
        bail!("non-finite outputs");
    }
    Ok(())
}

// -------------------------------------------------------------- generate

/// Autoregressive decoding over the native model path: byte-level prompts
/// through the continuous-batching scheduler.  O(1)/token for the linear
/// mechanisms, KV-cache fallback for the softmax family — deterministic in
/// `--seed` regardless of batching.
fn cmd_generate(argv: &[String]) -> Result<()> {
    let spec = Args::new("psf generate", "autoregressive decoding on the native model path")
        .opt("mech", "psk4_r16_b32_local",
             "mechanism label (softmax | flash_b<B> | poly<P> | psk<P>_r<R>_b<B>[_local] | performer<M>_b<B>)")
        .opt("checkpoint", "",
             "load trained weights from a `psf train-native` checkpoint \
              (overrides --mech/--d-model/--layers/--heads/--seed)")
        .opt("prompt", "The polynomial kernel ", "prompt text (byte-level tokens)")
        .opt("max-tokens", "64", "tokens to generate per session")
        .opt("sessions", "1", "concurrent sessions (same prompt, forked sampling seeds)")
        .opt("policy", "greedy", "greedy | temperature | top-k | top-p")
        .opt("temperature", "1.0", "softmax temperature (non-greedy policies)")
        .opt("top-k", "40", "k for --policy top-k")
        .opt("top-p", "0.9", "p for --policy top-p")
        .opt("d-model", "64", "model width")
        .opt("layers", "2", "transformer layers")
        .opt("heads", "4", "attention heads")
        .opt("concurrent", "4", "scheduler admission cap")
        .opt("tick", "16", "decode-token budget per scheduling tick")
        .opt("threads", "0", "compute threads (0 = PSF_THREADS env, else all cores)")
        .opt("log", "", "JSONL metrics path (empty = none)")
        .opt("seed", "0", "weight + sampling seed");
    let p = parse(spec, argv)?;
    apply_threads(&p)?;

    let model = load_native_model(&p)?;
    let mech = model.mech.clone();
    let policy = SamplePolicy::from_flags(
        p.str("policy"),
        p.f64("temperature")? as f32,
        p.usize("top-k")?,
        p.f64("top-p")? as f32,
    )
    .map_err(|e| anyhow!("{e}"))?;
    let seed = p.u64("seed")?;
    let sessions = p.usize("sessions")?.max(1);
    println!(
        "generate: mech {} ({}), d_model {} x {} layers, {} session(s)",
        mech.label(),
        if mech.is_linear() { "O(1)/token recurrent state" } else { "O(n)/token KV cache" },
        model.cfg.d_model,
        model.cfg.layers,
        sessions,
    );

    let prompt = infer::encode_prompt(p.str("prompt"));
    if prompt.iter().any(|&t| t as usize >= model.cfg.vocab) {
        bail!(
            "model vocab {} is too small for byte-level prompts (checkpoints from \
             `psf train-native --task lm` have vocab 257; task checkpoints do not)",
            model.cfg.vocab
        );
    }
    let sched_cfg = SchedulerConfig {
        max_concurrent: p.usize("concurrent")?,
        tick_tokens: p.usize("tick")?,
        log_path: non_empty(p.str("log")).map(PathBuf::from),
        echo: true,
    };
    let mut sched = Scheduler::new(&model, sched_cfg);
    for i in 0..sessions {
        sched.submit(infer::GenRequest {
            prompt: prompt.clone(),
            max_new_tokens: p.usize("max-tokens")?,
            policy: policy.clone(),
            seed: seed ^ (i as u64).wrapping_mul(0x9e3779b97f4a7c15),
        });
    }
    let summary = sched.run()?;
    for r in &summary.reports {
        println!(
            "--- session {} ({} new tokens, prefill {:.1}ms, {:.2}ms/token) ---",
            r.id,
            r.new_tokens,
            r.prefill_secs * 1e3,
            r.decode_secs * 1e3 / r.new_tokens.max(1) as f64,
        );
        println!("{}{}", p.str("prompt"), infer::decode_text(&r.tokens[r.prompt_len..]));
    }
    println!(
        "served {} session(s): {} tokens in {:.2}s = {:.1} tok/s aggregate \
         (step p50 {:.2}ms, p95 {:.2}ms)",
        summary.reports.len(),
        summary.total_new_tokens,
        summary.wall_secs,
        summary.tokens_per_sec,
        summary.p50_step_ms,
        summary.p95_step_ms,
    );
    Ok(())
}

// --------------------------------------------------------------- serve

/// HTTP serving gateway on the native model path: concurrent decode
/// workers (continuous batching across threads) + a prompt-prefix state
/// cache that skips prefill for repeated prompts — constant-size entries
/// for the linear mechanisms, O(n) KV entries for the softmax family.
///
/// `--runners N` switches to multi-process sharded serving: the gateway
/// spawns N `psf runner` worker processes (full replicas, or contiguous
/// head shards with `--tp`), routes requests over Unix-socket IPC by
/// consistent-hashing the prompt-cache key, and survives runner crashes
/// by respawning from the same model args.  Either way SIGTERM/SIGINT
/// drains in-flight work and flushes the closing metrics record.
fn cmd_serve(argv: &[String]) -> Result<()> {
    let spec = Args::new("psf serve", "HTTP serving gateway on the native model path")
        .opt("addr", "127.0.0.1:8080", "listen address (port 0 = ephemeral)")
        .opt("mech", "psk4_r16_b32_local",
             "mechanism label (softmax | flash_b<B> | poly<P> | psk<P>_r<R>_b<B>[_local] | performer<M>_b<B>)")
        .opt("checkpoint", "",
             "load trained weights from a `psf train-native` checkpoint \
              (overrides --mech/--d-model/--layers/--heads/--seed)")
        .opt("runners", "0",
             "model-runner worker processes (0 = single-process in-thread serving)")
        .switch("tp", "head-shard one model across the runners (tensor \
                 parallelism) instead of full data-parallel replicas")
        .opt("heartbeat-ms", "500", "runner heartbeat cadence in milliseconds")
        .opt("workers", "2", "decode worker threads (per runner when sharded)")
        .opt("queue-cap", "64", "admission queue depth (429 beyond it)")
        .opt("resident", "8", "max concurrent sessions across workers")
        .opt("slice", "4", "tokens per worker grab (fairness dial)")
        .opt("cache-mb", "64", "prompt-prefix cache budget in MiB (per runner when sharded)")
        .opt("default-max-tokens", "64", "max_tokens when the request omits it")
        .opt("max-tokens-cap", "512", "hard per-request max_tokens ceiling")
        .opt("d-model", "64", "model width")
        .opt("layers", "2", "transformer layers")
        .opt("heads", "4", "attention heads")
        .opt("threads", "0",
             "compute threads (0 = PSF_THREADS env, else all cores; \
              sharded: cores divided evenly across runners)")
        .opt("log", "", "JSONL metrics path (empty = none)")
        .opt("trace", "",
             "write a Chrome trace-event / Perfetto file here on drain \
              (sharded runs merge per-runner traces in; also via PSF_TRACE)")
        .opt("incident", "",
             "arm incident dumps: panic / sentinel trip / runner death / \
              SIGTERM writes this file (also via PSF_INCIDENT)")
        .opt("max-requests", "0", "stop after N completed requests (0 = run forever)")
        .opt("seed", "0", "weight seed");
    let p = parse(spec, argv)?;
    apply_threads(&p)?;

    let trace_path = non_empty(p.str("trace")).map(PathBuf::from);
    if let Some(tp) = &trace_path {
        polysketchformer::obs::init_tracing(tp);
    }
    let incident_path = non_empty(p.str("incident")).map(PathBuf::from);
    if let Some(ip) = &incident_path {
        arm_incident(ip);
    }

    let model = load_native_model(&p)?;
    if model.cfg.vocab < 257 {
        bail!(
            "serve needs byte-level vocab (>= 257); checkpoint has vocab {} — \
             train with `psf train-native --task lm`",
            model.cfg.vocab
        );
    }

    let runners = p.usize("runners")?;
    if runners == 0 {
        let gw_cfg = GatewayConfig {
            addr: p.str("addr").to_string(),
            workers: p.usize("workers")?,
            queue_cap: p.usize("queue-cap")?,
            max_resident: p.usize("resident")?,
            slice_tokens: p.usize("slice")?,
            cache_bytes: p.usize("cache-mb")? << 20,
            default_max_tokens: p.usize("default-max-tokens")?,
            max_tokens_cap: p.usize("max-tokens-cap")?,
            log_path: non_empty(p.str("log")).map(PathBuf::from),
            max_requests: p.u64("max-requests")?,
        };
        let gateway = std::sync::Arc::new(Gateway::new(model, gw_cfg)?);
        spawn_signal_watcher(gateway.stop_handle());
        polysketchformer::util::signal::on_shutdown(|| {
            flush_serve_trace(Vec::new());
            dump_incident_on_signal();
        });
        let result = gateway.run_http();
        // The drain path (signal or max-requests) funnels through here;
        // hooks flush the trace exactly once.
        polysketchformer::util::signal::run_shutdown_hooks();
        return result;
    }

    // Multi-process sharded serving.  The gateway loaded the model only
    // to validate it and read mech + head count; the runner processes
    // own the actual replicas/shards (built from the same args, which is
    // what makes them byte-equivalent to each other and to respawns).
    let mech = model.mech.clone();
    let heads = model.cfg.heads;
    let model_args: Vec<String> = match non_empty(p.str("checkpoint")) {
        Some(ck) => vec!["--checkpoint".into(), ck.to_string()],
        None => vec![
            "--mech".into(),
            mech.label(),
            "--d-model".into(),
            model.cfg.d_model.to_string(),
            "--layers".into(),
            model.cfg.layers.to_string(),
            "--heads".into(),
            heads.to_string(),
            "--seed".into(),
            p.str("seed").to_string(),
        ],
    };
    drop(model);

    let threads = p.usize("threads")?;
    let sup_cfg = shard::SupervisorConfig {
        runners,
        runner_exe: std::env::current_exe()?,
        model_args,
        runner_workers: p.usize("workers")?,
        slice_tokens: p.usize("slice")?,
        max_resident: p.usize("resident")?,
        queue_cap: p.usize("queue-cap")?,
        cache_mb: p.usize("cache-mb")?,
        threads_per_runner: if threads > 0 {
            threads
        } else {
            polysketchformer::exec::pool::per_process_threads(runners)
        },
        heartbeat_ms: p.u64("heartbeat-ms")?,
        tp: p.flag("tp"),
        heads,
        trace_base: trace_path.clone(),
        incident_base: incident_path.clone(),
        ..shard::SupervisorConfig::default()
    };
    let sup = shard::Supervisor::start(sup_cfg)?;
    // The gateway's own incident dump embeds whatever per-runner incident
    // files exist at dump time.
    polysketchformer::obs::incident::set_runner_files(sup.runner_incident_paths());
    let shard_cfg = shard::ShardConfig {
        addr: p.str("addr").to_string(),
        default_max_tokens: p.usize("default-max-tokens")?,
        max_tokens_cap: p.usize("max-tokens-cap")?,
        log_path: non_empty(p.str("log")).map(PathBuf::from),
        max_requests: p.u64("max-requests")?,
    };
    let gateway = std::sync::Arc::new(shard::ShardGateway::new(sup, mech, shard_cfg)?);
    spawn_signal_watcher(gateway.stop_handle());
    {
        // Runner children flush their own `<trace>.runnerN` files when the
        // Shutdown frame drains them (the supervisor reaps each child
        // before `run_http` returns), so merging here sees them on disk.
        let sup = std::sync::Arc::clone(gateway.supervisor());
        polysketchformer::util::signal::on_shutdown(move || {
            flush_serve_trace(sup.runner_trace_paths());
            dump_incident_on_signal();
        });
    }
    let result = std::sync::Arc::clone(&gateway).run_http();
    polysketchformer::util::signal::run_shutdown_hooks();
    result
}

/// Arm the incident machinery for a serve/runner process: configure the
/// dump path, install the panic hook, and start the flight recorder so a
/// dump carries a time-series window (same as `PSF_INCIDENT=<path>`).
fn arm_incident(path: &std::path::Path) {
    use polysketchformer::obs::{incident, recorder};
    incident::configure(path);
    incident::install_panic_hook();
    recorder::start(recorder::DEFAULT_INTERVAL_MS, recorder::DEFAULT_WINDOW_FRAMES);
}

/// On the signal drain path, snapshot an incident dump too — a SIGTERM'd
/// deploy leaves the same postmortem artifact a crash would (first write
/// wins, so an earlier panic/sentinel dump is never clobbered).
fn dump_incident_on_signal() {
    use polysketchformer::obs::incident;
    if polysketchformer::util::signal::triggered() && incident::configured() {
        let _ = incident::dump("shutdown signal");
    }
}

/// Drain this process's spans to the configured trace file, then fold in
/// the per-runner trace files (sharded serving) for one Perfetto-loadable
/// timeline where a request's gateway and runner spans share a trace id.
fn flush_serve_trace(runner_traces: Vec<PathBuf>) {
    use polysketchformer::obs;
    match obs::flush() {
        Ok(Some(path)) => {
            if !runner_traces.is_empty() {
                match obs::trace::merge_files(&path, &runner_traces) {
                    Ok(n) => eprintln!("psf serve: merged {n} runner trace file(s)"),
                    Err(e) => eprintln!("psf serve: runner trace merge failed: {e}"),
                }
            }
            eprintln!("psf serve: trace written to {}", path.display());
        }
        Ok(None) => {}
        Err(e) => eprintln!("psf serve: trace flush failed: {e}"),
    }
}

// ---------------------------------------------------------------- runner

/// The model-runner process body (hidden subcommand): connect back to
/// the supervisor socket, announce a `Hello`, then serve multiplexed
/// request frames until the gateway goes away.  Spawned by `psf serve
/// --runners N`; never invoked by hand, hence absent from `top_usage`.
fn cmd_runner(argv: &[String]) -> Result<()> {
    let spec = Args::new("psf runner", "model-runner process (spawned by `psf serve --runners`)")
        .req("socket", "supervisor Unix socket to connect back to")
        .opt("id", "0", "runner id assigned by the supervisor")
        .opt("mech", "psk4_r16_b32_local", "mechanism label")
        .opt("checkpoint", "",
             "load trained weights from a checkpoint \
              (overrides --mech/--d-model/--layers/--heads/--seed)")
        .opt("d-model", "64", "model width")
        .opt("layers", "2", "transformer layers")
        .opt("heads", "4", "attention heads")
        .opt("workers", "2", "decode worker threads")
        .opt("slice", "4", "tokens per worker grab")
        .opt("resident", "8", "max concurrent sessions")
        .opt("queue-cap", "64", "admission queue depth")
        .opt("cache-mb", "64", "prompt-prefix cache budget in MiB")
        .opt("threads", "0", "compute threads (0 = PSF_THREADS env, else all cores)")
        .opt("head-start", "0", "first head of this shard (TP mode)")
        .opt("head-end", "0", "one-past-last head of this shard (0 = full replica)")
        .opt("trace", "", "write this runner's trace-event file here on drain")
        .opt("incident", "", "write this runner's incident dump here on panic/trip")
        .opt("seed", "0", "weight seed");
    let p = parse(spec, argv)?;
    apply_threads(&p)?;
    if let Some(tp) = non_empty(p.str("trace")) {
        polysketchformer::obs::init_tracing(std::path::Path::new(tp));
    }
    if let Some(ip) = non_empty(p.str("incident")) {
        arm_incident(std::path::Path::new(ip));
    }

    let model = load_native_model(&p)?;
    if model.cfg.vocab < 257 {
        bail!(
            "runner needs byte-level vocab (>= 257); checkpoint has vocab {}",
            model.cfg.vocab
        );
    }
    let cfg = shard::RunnerConfig {
        socket: PathBuf::from(p.str("socket")),
        runner_id: p.u64("id")? as u32,
        worker: WorkerConfig {
            workers: p.usize("workers")?,
            slice_tokens: p.usize("slice")?,
            max_resident: p.usize("resident")?,
        },
        queue_cap: p.usize("queue-cap")?,
        cache_bytes: p.usize("cache-mb")? << 20,
        head_start: p.usize("head-start")?,
        head_end: p.usize("head-end")?,
    };
    shard::run_runner(model, cfg)
}

/// Arm SIGINT/SIGTERM for graceful shutdown: the watcher thread flips
/// the gateway's stop flag, which makes the HTTP accept loop exit,
/// workers drain, and the closing `serve_metrics` record flush —
/// instead of the process dying mid-request.
fn spawn_signal_watcher(stop: std::sync::Arc<std::sync::atomic::AtomicBool>) {
    polysketchformer::util::signal::install();
    std::thread::spawn(move || {
        while !polysketchformer::util::signal::triggered() {
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        stop.store(true, std::sync::atomic::Ordering::SeqCst);
    });
}

/// Build the native model for `generate`/`serve`: from a `--checkpoint`
/// file when given (trained weights are servable — config + mechanism
/// come from the checkpoint's meta sections), otherwise fresh
/// deterministic weights from the `--mech`/`--d-model`/... flags.
fn load_native_model(p: &polysketchformer::cli::Parsed) -> Result<NativeLm> {
    match non_empty(p.str("checkpoint")) {
        Some(ck) => {
            let (model, step) = NativeLm::load_checkpoint(std::path::Path::new(ck))?;
            eprintln!(
                "loaded checkpoint {ck} (step {step}, mech {}, d_model {} x {} layers)",
                model.mech.label(),
                model.cfg.d_model,
                model.cfg.layers,
            );
            Ok(model)
        }
        None => {
            let mech = Mechanism::parse(p.str("mech")).map_err(|e| anyhow!("{e}"))?;
            Ok(NativeLm::new(native_lm_config(p)?, mech))
        }
    }
}

/// Shared `--d-model/--layers/--heads/--seed` surface of the native-model
/// subcommands (`generate`, `serve`), with the head-dim validation the
/// kernels require (even head_dim for RoPE pairs).
fn native_lm_config(p: &polysketchformer::cli::Parsed) -> Result<LmConfig> {
    let cfg = LmConfig {
        d_model: p.usize("d-model")?,
        layers: p.usize("layers")?,
        heads: p.usize("heads")?,
        seed: p.u64("seed")?,
        ..LmConfig::default()
    };
    if cfg.heads == 0
        || cfg.layers == 0
        || cfg.d_model % cfg.heads != 0
        || (cfg.d_model / cfg.heads) % 2 != 0
    {
        bail!(
            "--d-model {} must split into --heads {} (>= 1) with an even head_dim, --layers >= 1",
            cfg.d_model,
            cfg.heads
        );
    }
    Ok(cfg)
}

/// Apply `--threads` to the deterministic compute backend before any
/// parallel work runs.  0 keeps the default sizing (PSF_THREADS env var,
/// else available cores).  By the backend's determinism contract the
/// thread count can never change outputs — only wall time.
fn apply_threads(p: &polysketchformer::cli::Parsed) -> Result<()> {
    let t = p.usize("threads")?;
    if t > 0 {
        polysketchformer::exec::pool::set_threads(t);
    }
    eprintln!("compute threads: {}", polysketchformer::exec::pool::threads());
    Ok(())
}

fn non_empty(s: &str) -> Option<&str> {
    (!s.is_empty()).then_some(s)
}
