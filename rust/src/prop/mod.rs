//! Property-testing mini-framework (no proptest in this environment).
//!
//! Generative testing with deterministic seeds and first-failure shrinking
//! over a size parameter: generators receive (rng, size); on failure the
//! runner retries with smaller sizes to report a minimal-ish case.
//!
//! ```ignore
//! prop::check("sorted idempotent", 100, |rng, size| {
//!     let mut xs = prop::gen_vec_f32(rng, size, -1e3..1e3);
//!     xs.sort_by(f32::total_cmp); let once = xs.clone();
//!     xs.sort_by(f32::total_cmp);
//!     prop::ensure(xs == once, "second sort changed order")
//! });
//! ```

use crate::util::rng::Pcg;
use std::ops::Range;

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Assertion helper.
pub fn ensure(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Approximate float equality with mixed abs/rel tolerance.
pub fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

/// Run `cases` random evaluations of `prop`, shrinking the size on failure.
/// Panics (failing the enclosing #[test]) with seed + size of the minimal
/// reproduction found.
pub fn check(name: &str, cases: u32, prop: impl Fn(&mut Pcg, usize) -> PropResult) {
    check_seeded(name, 0, cases, prop)
}

pub fn check_seeded(name: &str, seed: u64, cases: u32,
                    prop: impl Fn(&mut Pcg, usize) -> PropResult) {
    let mut root = Pcg::new(seed ^ hash_name(name), 0x5eed);
    for case in 0..cases {
        // Sizes sweep small -> large so early failures are already small.
        let size = 1 + (case as usize * 97 % 64);
        let case_seed = root.next_u64();
        let mut rng = Pcg::new(case_seed, case as u64);
        if let Err(msg) = prop(&mut rng, size) {
            // Shrink: retry smaller sizes with the same stream seed.
            let mut minimal = (size, msg.clone());
            for s in (1..size).rev() {
                let mut rng = Pcg::new(case_seed, case as u64);
                if let Err(m) = prop(&mut rng, s) {
                    minimal = (s, m);
                } else {
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {case_seed:#x}, \
                 size {size}; minimal size {}): {}",
                minimal.0, minimal.1
            );
        }
    }
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

// ------------------------------------------------------------ generators

pub fn gen_f32(rng: &mut Pcg, range: Range<f32>) -> f32 {
    range.start + rng.f32() * (range.end - range.start)
}

pub fn gen_vec_f32(rng: &mut Pcg, len: usize, range: Range<f32>) -> Vec<f32> {
    (0..len).map(|_| gen_f32(rng, range.clone())).collect()
}

pub fn gen_usize(rng: &mut Pcg, range: Range<usize>) -> usize {
    range.start + rng.usize_below(range.end - range.start)
}

/// Random power of two in [lo, hi] (inclusive, both powers of two).
pub fn gen_pow2(rng: &mut Pcg, lo: usize, hi: usize) -> usize {
    debug_assert!(lo.is_power_of_two() && hi.is_power_of_two() && lo <= hi);
    let lo_exp = lo.trailing_zeros();
    let hi_exp = hi.trailing_zeros();
    1 << (lo_exp + rng.next_u32() % (hi_exp - lo_exp + 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", 50, |rng, size| {
            let a = gen_vec_f32(rng, size, -10.0..10.0);
            let b = gen_vec_f32(rng, size, -10.0..10.0);
            let ab: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
            let ba: Vec<f32> = b.iter().zip(&a).map(|(x, y)| x + y).collect();
            ensure(ab == ba, "a+b != b+a")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_context() {
        check("always fails", 10, |_, _| Err("nope".into()));
    }

    #[test]
    fn shrinking_reports_small_size() {
        let result = std::panic::catch_unwind(|| {
            check("fails at >=4", 50, |_, size| ensure(size < 4, "too big"));
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("minimal size 4"), "{msg}");
    }

    #[test]
    fn gen_pow2_in_range() {
        let mut rng = Pcg::seeded(0);
        for _ in 0..100 {
            let v = gen_pow2(&mut rng, 2, 64);
            assert!(v.is_power_of_two() && (2..=64).contains(&v));
        }
    }

    #[test]
    fn close_tolerance() {
        assert!(close(1.0, 1.0 + 1e-7, 1e-5));
        assert!(!close(1.0, 1.1, 1e-5));
    }
}
