//! Appendix F.2 — Induction Heads accuracy per attention mechanism.
//!
//! The paper trains 2-layer models on the induction-heads task and finds
//! every mechanism (softmax, poly 4/8, polysketch r=16/32) solves it at
//! ctx 128 (>99.95%) and every mechanism fails at ctx 256 (~1/16 random)
//! under the same optimization configuration.
//!
//! Here: the induction artifacts at ctx 128, softmax vs polysketch, with
//! random-guess baseline printed for reference.

use polysketchformer::bench::{banner, Mode, Table};
use polysketchformer::coordinator::{run_task, TaskRunnerConfig};
use polysketchformer::runtime::{self, LoadOpts};
use polysketchformer::tasks::induction::InductionTask;

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("induction_heads", "Appendix F.2 (induction heads accuracy)", mode);
    let steps = mode.pick(10, 400, 4000);
    let eval_examples = mode.pick(16, 128, 512);

    let artifacts = [
        ("softmax", "induction_softmax"),
        ("psk learned+local r16", "induction_psk"),
    ];

    let mut table = Table::new(
        &format!("Appendix F.2 analog — induction heads exact-match % after {steps} steps (ctx 128)"),
        "mechanism",
        vec!["accuracy %".into(), "steps to >90%".into()],
    );
    println!("random-guess baseline: {:.1}%\n", 100.0 / 16.0);

    for (label, name) in artifacts {
        let mut model = match runtime::load_model(name, LoadOpts::default()) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("  [skip {name}: {e}]");
                table.row(label, vec!["-".into(), "-".into()]);
                continue;
            }
        };
        let task = InductionTask::standard(model.ctx());
        let cfg = TaskRunnerConfig {
            steps,
            eval_every: (steps / 10).max(1),
            eval_examples,
            echo_every: 0,
            seed: 0,
            stop_at_accuracy: 0.999,
        };
        let summary = run_task(&mut model, &task, &cfg)?;
        println!("{label} accuracy curve:");
        for &(step, acc) in &summary.curve {
            println!("  step {step:>6}  {:>6.1}%", acc.exact * 100.0);
        }
        let jump = summary
            .curve
            .iter()
            .find(|&&(_, a)| a.exact > 0.9)
            .map(|&(s, _)| s.to_string())
            .unwrap_or_else(|| "-".into());
        table.row(
            label,
            vec![format!("{:.1}", summary.final_accuracy.exact * 100.0), jump],
        );
        println!("{label} done\n");
    }
    print!("{}", table.render());
    println!("csv: {}", table.save_csv("induction_heads")?.display());
    Ok(())
}
