//! Appendix F.2 — Induction Heads accuracy per attention mechanism,
//! trained **natively** (in-crate backprop through the kernel core; no
//! PJRT artifacts required).
//!
//! The paper trains 2-layer models on the induction-heads task and finds
//! every mechanism (softmax, poly, polysketch) solves it at ctx 128
//! (>99.95%) under the same optimization configuration.  Here: the same
//! task at ctx 128, softmax vs exact poly vs polysketch (local-exact),
//! each trained with AdamW + cosine from the same seed, with the
//! accuracy-vs-steps curve printed per mechanism and persisted to
//! `bench_out/induction_heads.json`.

use polysketchformer::attn::Mechanism;
use polysketchformer::bench::{banner, write_json, Mode, Table};
use polysketchformer::infer::{LmConfig, NativeLm};
use polysketchformer::metrics::Record;
use polysketchformer::tasks::induction::InductionTask;
use polysketchformer::train::{OptimConfig, TrainConfig, TrainSource, Trainer};

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("induction_heads", "Appendix F.2 (induction heads accuracy, native training)", mode);
    let steps = mode.pick(10, 400, 4000);
    let eval_examples = mode.pick(16, 128, 512);
    let ctx = mode.pick(32, 128, 128);

    let mechs = [
        ("softmax", "softmax"),
        ("poly (p=4)", "poly4"),
        ("psk r=16 + local", "psk4_r16_b32_local"),
    ];

    let mut table = Table::new(
        &format!(
            "Appendix F.2 analog — induction heads answer accuracy % after {steps} steps (ctx {ctx})"
        ),
        "mechanism",
        vec!["accuracy %".into(), "steps to >90%".into()],
    );
    println!("random-guess baseline: {:.1}%\n", 100.0 / 16.0);
    let mut records: Vec<Record> = Vec::new();

    for (label, mech_label) in mechs {
        let task = InductionTask::standard(ctx);
        let mech = Mechanism::parse(mech_label).expect("bench mechanism");
        let mut model = NativeLm::new(
            LmConfig {
                vocab: task.vocab(),
                d_model: 64,
                layers: 2,
                heads: 4,
                seed: 0,
                ..LmConfig::default()
            },
            mech,
        );
        let cfg = TrainConfig {
            steps,
            batch: 16,
            optim: OptimConfig { lr: 3e-3, warmup: 20, total_steps: steps, ..Default::default() },
            seed: 0,
            eval_every: (steps / 10).max(1),
            eval_examples,
            stop_at_accuracy: 0.999,
            echo_every: 0,
            log_path: None,
            ckpt_path: None,
            ckpt_every: 0,
        };
        let summary = Trainer::new(&mut model, TrainSource::Induction(task), cfg).run()?;
        println!("{label} accuracy curve:");
        for pt in &summary.curve {
            println!("  step {:>6}  {:>6.1}%  (loss {:.4})", pt.step, pt.accuracy * 100.0, pt.loss);
            records.push(
                Record::new()
                    .str("mech", mech_label)
                    .i64("step", pt.step as i64)
                    .f64("accuracy", pt.accuracy)
                    .f64("loss", pt.loss),
            );
        }
        let jump = summary
            .curve
            .iter()
            .find(|pt| pt.accuracy > 0.9)
            .map(|pt| pt.step.to_string())
            .unwrap_or_else(|| "-".into());
        table.row(label, vec![format!("{:.1}", summary.final_accuracy * 100.0), jump]);
        println!("{label} done ({} steps in {:.1}s)\n", summary.steps_run, summary.wall_secs);
    }
    print!("{}", table.render());
    println!("csv: {}", table.save_csv("induction_heads")?.display());

    let json_path = write_json(
        "induction_heads",
        &[("mode", format!("\"{mode:?}\"")), ("ctx", format!("{ctx}"))],
        &records,
    )?;
    println!("json: {}", json_path.display());
    Ok(())
}
