//! Kernel layout — per-head-copy vs strided-view prefill.
//!
//! The historical prefill path sliced each head's q/k/v columns into
//! fresh contiguous tensors (three (n, hd) copies per head) and
//! zero-padded every layer to the mechanism's block multiple; the kernel
//! core consumes strided [`TensorView`]s of the fused projections and
//! handles the ragged tail natively.  This bench reconstructs the old
//! layout faithfully (slice + pad + per-head forward + concat) and races
//! it against `kernel::prefill_heads` over n ∈ {1k, 8k, 32k} (full
//! mode), asserting along the way that both layouts produce *bitwise*
//! identical real rows — the padding-inertness argument, measured.
//!
//! Persists `bench_out/kernel_layout.json` and fails loudly
//! (KERNEL_LAYOUT_CHECK) if the view path is slower than the copy path
//! beyond timer noise on any swept n.

use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Instant;

use polysketchformer::attn::kernel::{prefill_heads, CausalKernel};
use polysketchformer::attn::Mechanism;
use polysketchformer::bench::{banner, out_dir, Mode};
use polysketchformer::metrics::Record;
use polysketchformer::tensor::Tensor;
use polysketchformer::util::rng::Pcg;

fn slice_head(t: &Tensor, head: usize, hd: usize) -> Tensor {
    let n = t.rows();
    let mut out = Tensor::zeros(&[n, hd]);
    for i in 0..n {
        out.row_mut(i).copy_from_slice(&t.row(i)[head * hd..(head + 1) * hd]);
    }
    out
}

fn pad_rows(t: &Tensor, np: usize) -> Tensor {
    let mut out = Tensor::zeros(&[np, t.cols()]);
    out.data_mut()[..t.len()].copy_from_slice(t.data());
    out
}

/// The pre-refactor layout: zero-pad to the block multiple, copy each
/// head's columns into owned tensors, run, concat the real rows.
fn copy_layout(
    kernels: &[Arc<dyn CausalKernel>],
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    hd: usize,
    block: usize,
) -> Tensor {
    let n = q.rows();
    let np = n.div_ceil(block) * block;
    let heads = kernels.len();
    let mut concat = Tensor::zeros(&[n, heads * hd]);
    for (hi, kernel) in kernels.iter().enumerate() {
        let qh = pad_rows(&slice_head(q, hi, hd), np);
        let kh = pad_rows(&slice_head(k, hi, hd), np);
        let vh = pad_rows(&slice_head(v, hi, hd), np);
        let oh = kernel.forward(&qh, &kh, &vh);
        for i in 0..n {
            concat.row_mut(i)[hi * hd..(hi + 1) * hd].copy_from_slice(&oh.row(i)[..hd]);
        }
    }
    concat
}

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("kernel_layout", "per-head-copy vs strided-view prefill", mode);

    let (heads, hd, block) = (4usize, 32usize, 256usize);
    let mech = Mechanism::Polysketch { r: 16, p: 4, block, local: true };
    let ns: &[usize] = match mode {
        Mode::Smoke => &[1024],
        Mode::Quick => &[1024, 8192],
        Mode::Full => &[1024, 8192, 32768],
    };
    let reps = mode.pick(2, 2, 1);

    let mut krng = Pcg::seeded(7);
    let kernels: Vec<Arc<dyn CausalKernel>> =
        (0..heads).map(|_| mech.build_kernel(hd, &mut krng)).collect();

    let mut records: Vec<Record> = Vec::new();
    let mut failures = Vec::new();
    println!("{:>8}  {:>12}  {:>12}  {:>8}", "n", "copy (s)", "view (s)", "view/copy");
    for &n in ns {
        // n+3: always exercise the ragged tail the old layout padded.
        let n = n + 3;
        let mut rng = Pcg::seeded(n as u64);
        let q = Tensor::gaussian(&mut rng, &[n, heads * hd]);
        let k = Tensor::gaussian(&mut rng, &[n, heads * hd]);
        let v = Tensor::gaussian(&mut rng, &[n, heads * hd]);

        // Correctness first: both layouts must agree bit for bit.
        let want = copy_layout(&kernels, &q, &k, &v, hd, block);
        let mut got = Tensor::zeros(&[n, heads * hd]);
        prefill_heads(&kernels, &q, &k, &v, None, &mut got);
        assert_eq!(got, want, "n={n}: strided-view prefill diverged from per-head copies");

        let mut copy_s = f64::INFINITY;
        let mut view_s = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let t0 = Instant::now();
            std::hint::black_box(copy_layout(&kernels, &q, &k, &v, hd, block));
            copy_s = copy_s.min(t0.elapsed().as_secs_f64());
            let t0 = Instant::now();
            let mut out = Tensor::zeros(&[n, heads * hd]);
            prefill_heads(&kernels, &q, &k, &v, None, &mut out);
            std::hint::black_box(out);
            view_s = view_s.min(t0.elapsed().as_secs_f64());
        }
        let ratio = view_s / copy_s.max(1e-12);
        println!("{n:>8}  {copy_s:>12.4}  {view_s:>12.4}  {ratio:>8.3}");
        for (layout, secs) in [("copy", copy_s), ("view", view_s)] {
            records.push(
                Record::new()
                    .str("layout", layout)
                    .str("mech", mech.label())
                    .i64("n", n as i64)
                    .i64("heads", heads as i64)
                    .i64("head_dim", hd as i64)
                    .f64("secs", secs),
            );
        }
        // Self-check per point: the view path must not be slower (15%
        // slack absorbs shared-runner timer noise).
        if view_s > copy_s * 1.15 {
            failures.push(format!("n={n}: view {view_s:.4}s vs copy {copy_s:.4}s"));
        }
    }

    let mut json = String::from("{\n  \"bench\": \"kernel_layout\",\n");
    let _ = writeln!(json, "  \"mode\": \"{mode:?}\",");
    let _ = writeln!(json, "  \"mech\": \"{}\",", mech.label());
    json.push_str("  \"results\": [\n");
    for (i, r) in records.iter().enumerate() {
        let _ = write!(json, "    {}", r.to_json());
        json.push_str(if i + 1 < records.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    let dir = out_dir();
    std::fs::create_dir_all(&dir)?;
    let json_path = dir.join("kernel_layout.json");
    std::fs::write(&json_path, json)?;
    println!("json: {}", json_path.display());

    if !failures.is_empty() {
        anyhow::bail!("KERNEL_LAYOUT_CHECK fail: {}", failures.join("; "));
    }
    println!("KERNEL_LAYOUT_CHECK pass: strided views never slower than per-head copies");
    Ok(())
}
