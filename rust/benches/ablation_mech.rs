//! Ablation — polysketch design choices: random vs learned sketches
//! (Section 2.3), ± local exact attention (Section 3.2), sketch size r.
//!
//! The paper's Tables 2-3 separate these axes; the consistent findings are
//! (i) learned sketches beat random, (ii) local exact attention helps both,
//! (iii) r=64 beats r=32, and (iv) learned+local matches softmax.  This
//! bench trains the artifact family at ctx 256 under an identical budget
//! and reports test perplexity per variant next to the softmax anchor.

use polysketchformer::bench::{banner, Mode, Table};
use polysketchformer::coordinator::{Trainer, TrainerConfig};
use polysketchformer::data::{self, batcher::Batcher, corpus::Flavor};
use polysketchformer::runtime::{self, LoadOpts};

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("ablation_mech", "Tables 2-3 polysketch variant columns", mode);
    let steps = mode.pick(6, 50, 600);
    let corpus_bytes = mode.pick(400_000, 3_000_000, 8_000_000);

    let variants: &[(&str, &str)] = &[
        ("softmax (anchor)", "softmax_v512_d128_l4_h4x32_c256"),
        ("psk learned+local r16", "psk4_r16_learned_local_v512_d128_l4_h4x32_c256"),
        ("psk learned r16 (no local)", "psk4_r16_learned_v512_d128_l4_h4x32_c256"),
        ("psk random+local r16", "psk4_r16_random_local_v512_d128_l4_h4x32_c256"),
        ("psk learned+local r8", "psk4_r8_learned_local_v512_d128_l4_h4x32_c256"),
    ];
    let variants = if mode == Mode::Smoke { &variants[..2] } else { variants };

    let mut table = Table::new(
        &format!("polysketch ablation — books corpus ppl after {steps} steps (ctx 256)"),
        "variant",
        vec!["test ppl".into(), "final train loss".into()],
    );

    for (label, name) in variants {
        let mut model = match runtime::load_model(
            name,
            LoadOpts { train: true, evalloss: true, fwd: false, grads: false },
        ) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("  [skip {name}: {e}]");
                table.row(label, vec!["-".into(), "-".into()]);
                continue;
            }
        };
        let ds = data::load_corpus_tokens(Flavor::Books, corpus_bytes, model.vocab(), 0, None)?;
        let train = Batcher::new(&ds.train, model.batch(), model.ctx() + 1, 0);
        let test = Batcher::new(&ds.test, model.batch(), model.ctx() + 1, 0);
        let cfg = TrainerConfig {
            steps,
            eval_every: 0,
            eval_batches: 8,
            ckpt_every: 0,
            echo_every: 0,
            run_dir: None,
            nan_guard: true,
        };
        let summary = Trainer::new(&mut model, train, Some(test), cfg).run()?;
        table.row(
            label,
            vec![
                format!("{:.2}", summary.final_perplexity()),
                format!("{:.3}", summary.final_loss),
            ],
        );
        println!("{label} done");
    }
    print!("{}", table.render());
    println!("csv: {}", table.save_csv("ablation_mech")?.display());
    Ok(())
}
