//! Table 4 — training steps/sec per attention mechanism vs context length
//! (higher is faster), at a fixed token budget per step.
//!
//! The paper's Table 4 shows linear transformers (Polysketch, Performer +
//! fast lower-triangular multiplication) hold nearly constant steps/sec as
//! context grows while quadratic mechanisms decay and OOM past 8k.
//!
//! Two parts, mirroring fig1_latency but reported in the paper's units:
//!   1. AOT fused train steps/sec across the artifact ctx family;
//!   2. native-kernel "attention steps/sec" out to 32k — one attention
//!      layer over a fixed 32k-token budget (batch*n constant), isolating
//!      the mechanism cost the table attributes the decay to.

use polysketchformer::attn::Mechanism;
use polysketchformer::bench::{banner, time_fn, Mode, Table};
use polysketchformer::data::random_tokens;
use polysketchformer::runtime::{self, LoadOpts};
use polysketchformer::tensor::Tensor;
use polysketchformer::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("table4_throughput", "Table 4 (training steps/sec)", mode);
    aot_part(mode)?;
    native_part(mode)?;
    Ok(())
}

fn aot_part(mode: Mode) -> anyhow::Result<()> {
    let iters = mode.pick(2, 4, 8);
    let mechs = [
        ("softmax", "softmax"),
        ("poly4", "poly4"),
        ("psk learned+local r16", "psk4_r16_learned_local"),
        ("psk random+local r16", "psk4_r16_random_local"),
        ("performer64", "performer64"),
    ];
    let ctxs = [64usize, 128, 256];
    let mut table = Table::new(
        "Table 4 analog — AOT train steps/sec (fixed 2048 tok/step)",
        "mechanism",
        ctxs.iter().map(|c| c.to_string()).collect(),
    );
    for (label, prefix) in mechs {
        let mut cells = Vec::new();
        for ctx in ctxs {
            let name = format!("{prefix}_v512_d128_l4_h4x32_c{ctx}");
            let mut model = match runtime::load_model(&name, LoadOpts::train_only()) {
                Ok(m) => m,
                Err(_) => {
                    cells.push("-".into());
                    continue;
                }
            };
            let tokens = random_tokens(model.batch() * (model.ctx() + 1), model.vocab(), 0)
                .into_iter()
                .map(|t| t as i32)
                .collect::<Vec<_>>();
            let t = time_fn(1, iters, || {
                model.train_step(&tokens).expect("train step");
            });
            cells.push(format!("{:.2}", 1.0 / t.mean_s));
        }
        table.row(label, cells);
    }
    print!("{}", table.render());
    println!("csv: {}\n", table.save_csv("table4_aot_steps_per_sec")?.display());
    Ok(())
}

fn native_part(mode: Mode) -> anyhow::Result<()> {
    let max_ctx = mode.pick(2048, 16384, 32768);
    let budget = max_ctx.max(8192); // tokens per "step"
    let head_dim = 32;
    let mechanisms = [
        Mechanism::Flash { block: 256 },
        Mechanism::Flash { block: 512 },
        Mechanism::Poly { p: 4 },
        Mechanism::Polysketch { r: 16, p: 4, block: 256, local: true },
        Mechanism::Polysketch { r: 32, p: 4, block: 256, local: true },
        Mechanism::Performer { m: 64, block: 256 },
    ];
    let mut ctxs = Vec::new();
    let mut c = 512usize;
    while c <= max_ctx {
        ctxs.push(c);
        c *= 2;
    }
    let mut table = Table::new(
        &format!("Table 4 analog — native attention steps/sec ({budget}-token budget)"),
        "mechanism",
        ctxs.iter().map(|c| c.to_string()).collect(),
    );
    let mut rng = Pcg::seeded(0);
    for mech in &mechanisms {
        let attn = mech.build_kernel(head_dim, &mut rng);
        let mut cells = Vec::new();
        for &n in &ctxs {
            if !mech.is_linear() && n > 16384 {
                cells.push("OOM".into());
                continue;
            }
            let reps = (budget / n).max(1);
            let q = Tensor::gaussian(&mut rng, &[n, head_dim]);
            let k = Tensor::gaussian(&mut rng, &[n, head_dim]);
            let v = Tensor::gaussian(&mut rng, &[n, head_dim]);
            let t = time_fn(0, 1, || {
                for _ in 0..reps {
                    std::hint::black_box(attn.forward(&q, &k, &v));
                }
            });
            cells.push(format!("{:.2}", 1.0 / t.mean_s));
        }
        table.row(&mech.label(), cells);
    }
    print!("{}", table.render());
    println!("csv: {}", table.save_csv("table4_native_steps_per_sec")?.display());
    Ok(())
}
