//! Tables 1 / 6 — C4 perplexity + downstream multiple-choice accuracy
//! (HellaSwag / PIQA / Physics analogs) per attention mechanism.
//!
//! The paper trains on C4 (0.5M-token batches) and scores MCQ tasks by
//! completion likelihood, 0-shot and 5-shot.  Here: the web-flavor
//! synthetic corpus, budget-matched training per mechanism, and synthetic
//! cloze MCQs (4-choice and 2-choice, the paper's two task arities) scored
//! by the same likelihood-argmax protocol.
//!
//! Expected shape (paper): polysketch learned+local within ~1-2% of softmax
//! on ppl and accuracy; accuracies well above chance; 5-shot ~ 0-shot at
//! this scale.

use polysketchformer::bench::{banner, Mode, Table};
use polysketchformer::coordinator::{self, Trainer, TrainerConfig};
use polysketchformer::data::{self, batcher::Batcher, corpus::Flavor};
use polysketchformer::runtime::{self, LoadOpts};

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("table1_downstream", "Table 1 (+ Table 6 at reduced budget)", mode);
    let steps = mode.pick(6, 50, 600);
    let questions = mode.pick(20, 100, 250);
    let corpus_bytes = mode.pick(400_000, 3_000_000, 8_000_000);
    let ctx = 256;

    let mechs: &[(&str, &str)] = &[
        ("softmax", "softmax"),
        ("poly (p=4)", "poly4"),
        ("poly (p=8)", "poly8"),
        ("psk learned+local r16", "psk4_r16_learned_local"),
        ("psk learned r16", "psk4_r16_learned"),
        ("psk random+local r16", "psk4_r16_random_local"),
        ("performer (64 feat)", "performer64"),
    ];
    let mechs = match mode {
        Mode::Smoke => &mechs[..2],
        Mode::Quick => &mechs[..5],
        Mode::Full => mechs,
    };

    let cols = vec![
        "ppl".into(),
        "cloze4 0s".into(),
        "cloze4 5s".into(),
        "cloze2 0s".into(),
        "cloze2 5s".into(),
    ];
    let mut table = Table::new(
        &format!("Table 1 analog — web corpus, ctx {ctx}, {steps} steps, {questions} questions"),
        "mechanism",
        cols,
    );

    for (label, prefix) in mechs {
        let name = format!("{prefix}_v512_d128_l4_h4x32_c{ctx}");
        match run_one(&name, steps, questions, corpus_bytes) {
            Ok(cells) => table.row(label, cells),
            Err(e) => {
                eprintln!("  [skip {name}: {e}]");
                table.row(label, vec!["-".into(); 5]);
            }
        }
        println!("{label} done");
    }
    print!("{}", table.render());
    println!("csv: {}", table.save_csv("table1_downstream")?.display());
    Ok(())
}

fn run_one(
    name: &str,
    steps: u64,
    questions: usize,
    corpus_bytes: usize,
) -> anyhow::Result<Vec<String>> {
    let mut model = runtime::load_model(name, LoadOpts::default())?;
    let ds = data::load_corpus_tokens(Flavor::Web, corpus_bytes, model.vocab(), 0, None)?;
    let train = Batcher::new(&ds.train, model.batch(), model.ctx() + 1, 0);
    let test = Batcher::new(&ds.test, model.batch(), model.ctx() + 1, 0);
    let cfg = TrainerConfig {
        steps,
        eval_every: 0,
        eval_batches: 8,
        ckpt_every: 0,
        echo_every: 0,
        run_dir: None,
        nan_guard: true,
    };
    let summary = Trainer::new(&mut model, train, Some(test), cfg).run()?;

    let mut cells = vec![format!("{:.2}", summary.final_perplexity())];
    for (choices, shots) in [(4usize, 0usize), (4, 5), (2, 0), (2, 5)] {
        let qs = coordinator::gen_cloze_questions(
            &ds.test,
            model.ctx(),
            questions,
            choices,
            16,
            shots,
            11,
        );
        let acc = coordinator::score_mcq(&model, &qs)?;
        cells.push(format!("{:.1}", acc * 100.0));
    }
    Ok(cells)
}
