//! Kernel profile — per-phase time breakdown of the attention engines.
//!
//! Runs prefill + a burst of decode steps for one linear (polysketch)
//! and one quadratic (softmax) kernel with the obs phase accumulators
//! on, then reports where the nanoseconds went: feature map vs diagonal
//! scores vs prefix multiply vs emit vs Z-fold for the linear engine,
//! attention vs state capture vs step for the quadratic one.  This JSON
//! (`bench_out/kernel_profile.json`) is the baseline the SIMD work
//! optimizes against — a phase that dominates here is the phase worth
//! vectorizing first.
//!
//! Doubles as a determinism check for the overhead contract: the same
//! prefill runs with phases off and on and must produce bitwise
//! identical output (timing is write-only telemetry).

use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Instant;

use polysketchformer::attn::kernel::CausalKernel;
use polysketchformer::attn::Mechanism;
use polysketchformer::bench::{banner, out_dir, Mode};
use polysketchformer::metrics::Record;
use polysketchformer::obs;
use polysketchformer::tensor::Tensor;
use polysketchformer::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("kernel_profile", "per-phase kernel time breakdown (obs accumulators)", mode);

    let hd = 32usize;
    // +3 keeps the ragged tail in play so block-edge phases are exercised.
    let n = mode.pick(512, 2048, 8192) + 3;
    let decode_steps = mode.pick(32, 128, 256);
    let mechs = ["psk4_r16_b32_local", "softmax"];

    let mut rng = Pcg::seeded(n as u64);
    let q = Tensor::gaussian(&mut rng, &[n, hd]);
    let k = Tensor::gaussian(&mut rng, &[n, hd]);
    let v = Tensor::gaussian(&mut rng, &[n, hd]);

    let mut records: Vec<Record> = Vec::new();
    let mut seen: Vec<(&str, &str)> = Vec::new();
    for label in mechs {
        let mech = Mechanism::parse(label).expect("bench mechanism");
        let kernel: Arc<dyn CausalKernel> = mech.build_kernel(hd, &mut Pcg::seeded(42));

        // Overhead contract: phases off vs on, bitwise identical output.
        obs::set_phases(false);
        let want = kernel.forward(&q, &k, &v);
        obs::set_phases(true);
        obs::phase::reset();

        let t0 = Instant::now();
        let mut state = kernel.new_state();
        let got = kernel.prefill(&q.view(), &k.view(), &v.view(), Some(&mut state));
        let prefill_secs = t0.elapsed().as_secs_f64();
        assert_eq!(got, want, "{label}: output changed with phase accounting on");

        let t0 = Instant::now();
        for i in 0..decode_steps {
            let row = (i * 7) % n;
            std::hint::black_box(kernel.step(q.row(row), k.row(row), v.row(row), &mut state));
        }
        let decode_secs = t0.elapsed().as_secs_f64();

        let totals = obs::phase::totals();
        obs::set_phases(false);
        let accounted: u64 = totals.iter().map(|(_, ns, _)| ns).sum();
        anyhow::ensure!(
            !totals.is_empty(),
            "{label}: no phase accumulated — kernel hooks are dead"
        );

        println!(
            "{label}: n={n} prefill {prefill_secs:.4}s, {decode_steps} decode steps {decode_secs:.4}s"
        );
        println!("  {:>14}  {:>12}  {:>10}  {:>7}", "phase", "nanos", "count", "share");
        for &(name, nanos, count) in &totals {
            let share = nanos as f64 / accounted.max(1) as f64;
            println!("  {name:>14}  {nanos:>12}  {count:>10}  {:>6.1}%", share * 100.0);
            seen.push((label, name));
            records.push(
                Record::new()
                    .str("mech", label)
                    .str("phase", name)
                    .i64("n", n as i64)
                    .i64("head_dim", hd as i64)
                    .i64("decode_steps", decode_steps as i64)
                    .i64("nanos", nanos as i64)
                    .i64("count", count as i64)
                    .f64("share", share)
                    .f64("prefill_secs", prefill_secs)
                    .f64("decode_secs", decode_secs),
            );
        }
    }

    let mut json = String::from("{\n  \"bench\": \"kernel_profile\",\n");
    let _ = writeln!(json, "  \"mode\": \"{mode:?}\",");
    let _ = writeln!(json, "  \"n\": {n},");
    let _ = writeln!(json, "  \"head_dim\": {hd},");
    let _ = writeln!(json, "  \"decode_steps\": {decode_steps},");
    json.push_str("  \"results\": [\n");
    for (i, r) in records.iter().enumerate() {
        let _ = write!(json, "    {}", r.to_json());
        json.push_str(if i + 1 < records.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    let dir = out_dir();
    std::fs::create_dir_all(&dir)?;
    let json_path = dir.join("kernel_profile.json");
    std::fs::write(&json_path, json)?;
    println!("json: {}", json_path.display());

    // The breakdown must cover the phases the SIMD work targets.
    for (m, p) in [
        ("psk4_r16_b32_local", "lin_map"),
        ("psk4_r16_b32_local", "lin_scores"),
        ("psk4_r16_b32_local", "lin_step"),
        ("softmax", "quad_attn"),
        ("softmax", "quad_step"),
    ] {
        anyhow::ensure!(
            seen.contains(&(m, p)),
            "KERNEL_PROFILE_CHECK fail: phase {p} missing for {m}"
        );
    }
    println!("KERNEL_PROFILE_CHECK pass: all target phases present, output bit-identical with phases on");
    Ok(())
}
