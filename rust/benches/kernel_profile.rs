//! Kernel profile — per-phase time breakdown of the attention engines,
//! scalar vs SIMD.
//!
//! Runs prefill + a burst of decode steps for one linear (polysketch)
//! and one quadratic (softmax) kernel with the obs phase accumulators
//! on, once under the forced scalar microkernel backend and once under
//! the best available SIMD backend, then reports where the nanoseconds
//! went per backend: feature map vs diagonal scores vs prefix multiply
//! vs emit vs Z-fold for the linear engine, attention vs state capture
//! vs step for the quadratic one.  The JSON
//! (`bench_out/kernel_profile.json`) carries both timings per phase plus
//! the speedup, so CI can watch the SIMD win per phase over time.
//!
//! Doubles as the determinism check for two contracts:
//! * phases off vs on must produce bitwise identical output (timing is
//!   write-only telemetry);
//! * the scalar and SIMD backends must produce bitwise identical prefill
//!   outputs AND decode streams — the lane-tree invariant, end to end.
//!
//! With `PSF_SIMD_CHECK=1` the run additionally *fails* if any phase
//! that spent meaningful time under the scalar backend got slower under
//! SIMD (beyond a noise allowance) — the CI gate that the vectorized
//! backends never regress below scalar throughput.

use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Instant;

use polysketchformer::attn::kernel::CausalKernel;
use polysketchformer::attn::Mechanism;
use polysketchformer::bench::{banner, out_dir, Mode};
use polysketchformer::infer::{DecodeSession, GenRequest, LmConfig, NativeLm, SamplePolicy};
use polysketchformer::mem::quant::{self, QuantMode};
use polysketchformer::metrics::Record;
use polysketchformer::obs;
use polysketchformer::serve::PromptCache;
use polysketchformer::tensor::{micro, Tensor};
use polysketchformer::util::rng::Pcg;

/// One profiled pass: prefill + decode burst under whatever microkernel
/// backend is currently active, with phase accumulators on.
struct ProfiledRun {
    prefill_out: Tensor,
    decode_outs: Vec<Vec<f32>>,
    totals: Vec<(&'static str, u64, u64)>,
    prefill_secs: f64,
    decode_secs: f64,
}

fn profile_run(
    kernel: &Arc<dyn CausalKernel>,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    decode_steps: usize,
) -> ProfiledRun {
    let n = q.rows();
    obs::phase::reset();
    let t0 = Instant::now();
    let mut state = kernel.new_state();
    let prefill_out = kernel.prefill(&q.view(), &k.view(), &v.view(), Some(&mut state));
    let prefill_secs = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let mut decode_outs = Vec::with_capacity(decode_steps);
    for i in 0..decode_steps {
        let row = (i * 7) % n;
        decode_outs.push(std::hint::black_box(kernel.step(
            q.row(row),
            k.row(row),
            v.row(row),
            &mut state,
        )));
    }
    let decode_secs = t0.elapsed().as_secs_f64();
    let totals = obs::phase::totals();
    ProfiledRun { prefill_out, decode_outs, totals, prefill_secs, decode_secs }
}

/// Phases faster than this under the scalar backend are too noisy to
/// gate on (one timer quantum can flip the comparison).
const GATE_FLOOR_NANOS: u64 = 200_000;
/// Noise allowance for the `PSF_SIMD_CHECK` gate: SIMD must stay within
/// this factor of scalar time for every gated phase.
const GATE_SLACK: f64 = 1.25;

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("kernel_profile", "per-phase kernel time breakdown, scalar vs simd", mode);

    let simd_check = std::env::var("PSF_SIMD_CHECK").map(|v| v == "1").unwrap_or(false);
    let best = micro::best_available();
    println!("microkernel backends: scalar vs {} (simd_check={simd_check})", best.label());

    let hd = 32usize;
    // +3 keeps the ragged tail in play so block-edge phases are exercised.
    let n = mode.pick(512, 2048, 8192) + 3;
    let decode_steps = mode.pick(32, 128, 256);
    let mechs = ["psk4_r16_b32_local", "softmax"];

    let mut rng = Pcg::seeded(n as u64);
    let q = Tensor::gaussian(&mut rng, &[n, hd]);
    let k = Tensor::gaussian(&mut rng, &[n, hd]);
    let v = Tensor::gaussian(&mut rng, &[n, hd]);

    let mut records: Vec<Record> = Vec::new();
    let mut seen: Vec<(&str, &str)> = Vec::new();
    let mut gate_failures: Vec<String> = Vec::new();
    for label in mechs {
        let mech = Mechanism::parse(label).expect("bench mechanism");
        let kernel: Arc<dyn CausalKernel> = mech.build_kernel(hd, &mut Pcg::seeded(42));

        // Overhead contract: phases off vs on, bitwise identical output.
        obs::set_phases(false);
        let want = kernel.forward(&q, &k, &v);
        obs::set_phases(true);

        micro::force_backend(micro::Backend::Scalar).expect("scalar backend");
        let scalar = profile_run(&kernel, &q, &k, &v, decode_steps);
        micro::force_backend(best).expect("detected backend");
        let simd = profile_run(&kernel, &q, &k, &v, decode_steps);
        micro::reset_backend();
        obs::set_phases(false);

        assert_eq!(
            scalar.prefill_out, want,
            "{label}: output changed with phase accounting on"
        );
        // The lane-tree contract, end to end: backends differ in speed
        // only, never in bytes — prefill logits and the decode stream.
        assert_eq!(
            scalar.prefill_out, simd.prefill_out,
            "{label}: scalar vs {} prefill bytes diverged",
            best.label()
        );
        assert_eq!(
            scalar.decode_outs, simd.decode_outs,
            "{label}: scalar vs {} decode bytes diverged",
            best.label()
        );

        let accounted: u64 = simd.totals.iter().map(|(_, ns, _)| ns).sum();
        anyhow::ensure!(
            !simd.totals.is_empty(),
            "{label}: no phase accumulated — kernel hooks are dead"
        );

        println!(
            "{label}: n={n} prefill scalar {:.4}s / {} {:.4}s, {decode_steps} decode steps scalar {:.4}s / {} {:.4}s",
            scalar.prefill_secs,
            best.label(),
            simd.prefill_secs,
            scalar.decode_secs,
            best.label(),
            simd.decode_secs,
        );
        println!(
            "  {:>14}  {:>12}  {:>12}  {:>8}  {:>10}  {:>7}",
            "phase", "scalar_ns", "simd_ns", "speedup", "count", "share"
        );
        for &(name, nanos, count) in &simd.totals {
            let scalar_nanos = scalar
                .totals
                .iter()
                .find(|(p, _, _)| *p == name)
                .map(|&(_, ns, _)| ns)
                .unwrap_or(0);
            let share = nanos as f64 / accounted.max(1) as f64;
            let speedup = scalar_nanos as f64 / nanos.max(1) as f64;
            println!(
                "  {name:>14}  {scalar_nanos:>12}  {nanos:>12}  {speedup:>7.2}x  {count:>10}  {:>6.1}%",
                share * 100.0
            );
            if best != micro::Backend::Scalar
                && scalar_nanos >= GATE_FLOOR_NANOS
                && (nanos as f64) > scalar_nanos as f64 * GATE_SLACK
            {
                gate_failures.push(format!(
                    "{label}/{name}: simd {nanos}ns > scalar {scalar_nanos}ns x{GATE_SLACK}"
                ));
            }
            seen.push((label, name));
            records.push(
                Record::new()
                    .str("mech", label)
                    .str("phase", name)
                    .str("simd_backend", best.label())
                    .i64("n", n as i64)
                    .i64("head_dim", hd as i64)
                    .i64("decode_steps", decode_steps as i64)
                    .i64("nanos", nanos as i64)
                    .i64("nanos_scalar", scalar_nanos as i64)
                    .i64("count", count as i64)
                    .f64("share", share)
                    .f64("speedup", speedup)
                    .f64("prefill_secs", simd.prefill_secs)
                    .f64("prefill_secs_scalar", scalar.prefill_secs)
                    .f64("decode_secs", simd.decode_secs)
                    .f64("decode_secs_scalar", scalar.decode_secs),
            );
        }
    }

    // ---- quantized decode profile: f32 vs int8 weight twins -----------
    //
    // Drives the full LM decode loop through a frozen/thawed prompt
    // prefix so the quantize (int8 twin build + compact-tier freeze) and
    // dequantize (thaw) phases show up in the breakdown alongside the
    // per-step cost of the q8 matvec path.
    let lm_steps = mode.pick(16, 64, 128);
    let lm_prompt: Vec<u32> = std::iter::once(0u32).chain((0..32u32).map(|i| 1 + (i * 13) % 60)).collect();
    obs::set_phases(true);
    for (tier, qm) in [("lm_decode:f32", QuantMode::Off), ("lm_decode:q8", QuantMode::Q8)] {
        quant::force_mode(qm);
        obs::phase::reset();
        let lm_cfg = LmConfig { d_model: 64, layers: 2, heads: 2, ..LmConfig::default() };
        let mut m = NativeLm::new(lm_cfg, Mechanism::parse("psk4_r16_b32_local").unwrap());
        m.requantize();
        let cache = PromptCache::new(32 << 20);
        let prefilled = DecodeSession::new(
            &m,
            0,
            GenRequest {
                prompt: lm_prompt.clone(),
                max_new_tokens: 0,
                policy: SamplePolicy::Greedy,
                seed: 0,
            },
        );
        let snap = cache.freeze(&prefilled);
        let (states, logits) = snap.thaw(&m);
        let mut s = DecodeSession::from_prefix(
            1,
            GenRequest {
                prompt: lm_prompt.clone(),
                max_new_tokens: lm_steps,
                policy: SamplePolicy::Greedy,
                seed: 0,
            },
            states,
            logits,
        );
        let t0 = Instant::now();
        s.run_to_completion(&m);
        let decode_secs = t0.elapsed().as_secs_f64();
        let totals = obs::phase::totals();
        quant::reset_mode();

        let tok_s = if decode_secs > 0.0 { lm_steps as f64 / decode_secs } else { 0.0 };
        println!("{tier}: {lm_steps} decode steps in {decode_secs:.4}s ({tok_s:.1} tok/s)");
        let accounted: u64 = totals.iter().map(|(_, ns, _)| ns).sum();
        for &(name, nanos, count) in &totals {
            let share = nanos as f64 / accounted.max(1) as f64;
            println!("  {name:>14}  {nanos:>12}  {count:>10}  {:>6.1}%", share * 100.0);
            seen.push((tier, name));
            records.push(
                Record::new()
                    .str("mech", tier)
                    .str("phase", name)
                    .str("simd_backend", best.label())
                    .i64("decode_steps", lm_steps as i64)
                    .i64("nanos", nanos as i64)
                    .i64("count", count as i64)
                    .f64("share", share)
                    .f64("decode_secs", decode_secs)
                    .f64("tokens_per_sec", tok_s),
            );
        }
    }
    obs::set_phases(false);

    let mut json = String::from("{\n  \"bench\": \"kernel_profile\",\n");
    let _ = writeln!(json, "  \"mode\": \"{mode:?}\",");
    let _ = writeln!(json, "  \"n\": {n},");
    let _ = writeln!(json, "  \"head_dim\": {hd},");
    let _ = writeln!(json, "  \"decode_steps\": {decode_steps},");
    let _ = writeln!(json, "  \"simd_backend\": \"{}\",", best.label());
    let _ = writeln!(json, "  \"simd_check\": {simd_check},");
    json.push_str("  \"results\": [\n");
    for (i, r) in records.iter().enumerate() {
        let _ = write!(json, "    {}", r.to_json());
        json.push_str(if i + 1 < records.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    let dir = out_dir();
    std::fs::create_dir_all(&dir)?;
    let json_path = dir.join("kernel_profile.json");
    std::fs::write(&json_path, json)?;
    println!("json: {}", json_path.display());

    // The breakdown must cover the phases the SIMD backends accelerate.
    for (m, p) in [
        ("psk4_r16_b32_local", "lin_map"),
        ("psk4_r16_b32_local", "lin_scores"),
        ("psk4_r16_b32_local", "lin_step"),
        ("softmax", "quad_attn"),
        ("softmax", "quad_step"),
        // The storage-tier phases: int8/f16 narrowing on freeze and the
        // widen-back on thaw, both exercised by the q8 lm_decode pass.
        ("lm_decode:q8", "quantize"),
        ("lm_decode:q8", "dequantize"),
    ] {
        anyhow::ensure!(
            seen.contains(&(m, p)),
            "KERNEL_PROFILE_CHECK fail: phase {p} missing for {m}"
        );
    }
    if simd_check {
        anyhow::ensure!(
            gate_failures.is_empty(),
            "PSF_SIMD_CHECK fail: SIMD slower than scalar on gated phases:\n  {}",
            gate_failures.join("\n  ")
        );
        println!("PSF_SIMD_CHECK pass: {} >= scalar throughput on every gated phase", best.label());
    } else if !gate_failures.is_empty() {
        println!("note (gate off): {}", gate_failures.join("; "));
    }
    println!("KERNEL_PROFILE_CHECK pass: all target phases present, scalar/simd byte-identical, output bit-identical with phases on");
    Ok(())
}
