//! Figure 2 / Tables 2–3 — pre-training perplexity per attention mechanism
//! across context lengths, on PG19-like and Wiki-40B-like corpora.
//!
//! The paper trains GPT-2-small models for 125k steps with 1M-token batches
//! at ctx 512..32k and reports test perplexity per mechanism.  Scaled to
//! this testbed: the artifact family (ctx 64/128/256, fixed 2048-token
//! budget per step) trained for a budget-matched number of steps on the
//! synthetic corpora, same tokenizer and eval protocol per column.
//!
//! Expected shape (paper): poly(p>=4) ≈ softmax; polysketch learned+local
//! matches or beats softmax; random-sketch and performer trail; ppl
//! improves with context.

use polysketchformer::bench::{banner, Mode, Table};
use polysketchformer::coordinator::{Trainer, TrainerConfig};
use polysketchformer::data::{self, batcher::Batcher, corpus::Flavor};
use polysketchformer::runtime::{self, LoadOpts};

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("fig2_perplexity", "Figure 2, Tables 2 and 3", mode);
    let steps = mode.pick(6, 50, 600);
    let corpus_bytes = mode.pick(400_000, 3_000_000, 8_000_000);

    // (row label, artifact prefix)
    // (random-sketch and r ablations live in ablation_mech.)
    let mechs: &[(&str, &str)] = &[
        ("softmax", "softmax"),
        ("poly (p=4)", "poly4"),
        ("psk learned+local r16", "psk4_r16_learned_local"),
        ("performer (64 feat)", "performer64"),
    ];
    let mechs = if mode == Mode::Smoke { &mechs[..2] } else { mechs };
    let ctxs: &[usize] = match mode {
        Mode::Smoke => &[64],
        Mode::Quick => &[64, 128],
        Mode::Full => &[64, 128, 256],
    };

    for flavor in [Flavor::Books, Flavor::Wiki] {
        let mut table = Table::new(
            &format!(
                "Fig 2 / Table {} analog — test perplexity on {} corpus ({} steps, 2048 tok/step)",
                if flavor == Flavor::Books { "2 (PG19)" } else { "3 (Wiki-40B)" },
                flavor.label(),
                steps,
            ),
            "mechanism",
            ctxs.iter().map(|c| c.to_string()).collect(),
        );

        for (label, prefix) in mechs {
            let mut cells = Vec::new();
            for &ctx in ctxs {
                let name = format!("{prefix}_v512_d128_l4_h4x32_c{ctx}");
                match train_and_eval(&name, flavor, steps, corpus_bytes) {
                    Ok(ppl) => cells.push(format!("{ppl:.2}")),
                    Err(e) => {
                        eprintln!("  [skip {name}: {e}]");
                        cells.push("-".into());
                    }
                }
            }
            table.row(label, cells);
            println!("{label} done");
        }
        print!("{}", table.render());
        let path = table.save_csv(&format!("fig2_ppl_{}", flavor.label()))?;
        println!("csv: {}\n", path.display());
    }
    Ok(())
}

fn train_and_eval(
    name: &str,
    flavor: Flavor,
    steps: u64,
    corpus_bytes: usize,
) -> anyhow::Result<f64> {
    let mut model = runtime::load_model(
        name,
        LoadOpts { train: true, evalloss: true, fwd: false, grads: false },
    )?;
    let ds = data::load_corpus_tokens(flavor, corpus_bytes, model.vocab(), 0, None)?;
    let train = Batcher::new(&ds.train, model.batch(), model.ctx() + 1, 0);
    let test = Batcher::new(&ds.test, model.batch(), model.ctx() + 1, 0);
    let cfg = TrainerConfig {
        steps,
        eval_every: 0,
        eval_batches: 8,
        ckpt_every: 0,
        echo_every: 0,
        run_dir: None,
        nan_guard: true,
    };
    let summary = Trainer::new(&mut model, train, Some(test), cfg).run()?;
    Ok(summary.final_perplexity())
}
