//! Ablation — block size b for the block lower-triangular multiplication
//! (Section 3.1; the paper uses b = 1024 and discusses the O(nb(m+k)) /
//! sequential-steps trade).
//!
//! Sweeps b at fixed context length and reports polysketch attention
//! latency plus the number of sequential prefix steps t = n/b.  Also
//! verifies the output is invariant in b (same math, different schedule).
//!
//! Expected shape: a U-curve — tiny b pays prefix-update overhead (many
//! sequential steps), huge b pays the O(b²) in-block cost; the paper's
//! choice sits at the flat bottom.

use polysketchformer::attn::Mechanism;
use polysketchformer::bench::{banner, time_fn, Mode, Table};
use polysketchformer::tensor::Tensor;
use polysketchformer::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("ablation_block", "Section 3.1 block-size trade (paper b=1024)", mode);
    let n = mode.pick(2048, 8192, 32768);
    let iters = mode.pick(1, 2, 3);
    let h = 32;
    let blocks = [32usize, 64, 128, 256, 512, 1024, 2048];

    let mut table = Table::new(
        &format!("block-lt ablation — polysketch r=16 p=4 local, n={n}"),
        "b",
        vec!["ms".into(), "us/token".into(), "prefix steps".into()],
    );

    let mut rng = Pcg::seeded(0);
    let q = Tensor::gaussian(&mut rng, &[n, h]);
    let k = Tensor::gaussian(&mut rng, &[n, h]);
    let v = Tensor::gaussian(&mut rng, &[n, h]);

    // b-invariance: outputs at every block size must match a reference.
    let reference = {
        let mech = Mechanism::Polysketch { r: 16, p: 4, block: blocks[0], local: false };
        mech.build_kernel(h, &mut Pcg::seeded(42)).forward(&q, &k, &v)
    };

    for &b in &blocks {
        if b > n {
            continue;
        }
        let mech = Mechanism::Polysketch { r: 16, p: 4, block: b, local: false };
        let attn = mech.build_kernel(h, &mut Pcg::seeded(42));
        let out = attn.forward(&q, &k, &v);
        let max_dev = out
            .data()
            .iter()
            .zip(reference.data())
            .map(|(a, r)| (a - r).abs())
            .fold(0.0f32, f32::max);
        assert!(
            max_dev < 2e-2,
            "block size must not change the math (b={b}, dev={max_dev})"
        );

        let t = time_fn(1, iters, || {
            std::hint::black_box(attn.forward(&q, &k, &v));
        });
        table.row(
            &b.to_string(),
            vec![
                format!("{:.1}", t.mean_ms()),
                format!("{:.2}", t.mean_us() / n as f64),
                (n / b).to_string(),
            ],
        );
        println!("b={b} done (max dev vs reference {max_dev:.2e})");
    }
    print!("{}", table.render());
    println!("csv: {}", table.save_csv("ablation_block")?.display());
    Ok(())
}
