//! Table 5 / Figure 5 — Selective Copying accuracy per attention
//! mechanism, trained **natively** (in-crate backprop; no PJRT
//! artifacts).
//!
//! The paper trains 2-layer models (8 heads × 16) on the selective
//! copying task and reports exact-match accuracy, observing a sudden
//! accuracy jump during training (Figure 5).  Scaled here: ctx 256,
//! softmax vs poly(4) vs polysketch (local-exact), with the per-token
//! accuracy-over-steps curve printed per mechanism and persisted to
//! `bench_out/table5_selective_copy.json`.
//!
//! Expected shape (paper): all mechanisms learn the task at in-budget
//! context lengths, with a visible sudden-learning jump.

use polysketchformer::attn::Mechanism;
use polysketchformer::bench::{banner, write_json, Mode, Table};
use polysketchformer::infer::{LmConfig, NativeLm};
use polysketchformer::metrics::Record;
use polysketchformer::tasks::selective_copy::SelectiveCopyTask;
use polysketchformer::train::{OptimConfig, TrainConfig, TrainSource, Trainer};

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("table5_selective_copy", "Table 5 + Figure 5 (accuracy curve, native training)", mode);
    let steps = mode.pick(10, 200, 2500);
    let eval_examples = mode.pick(16, 64, 256);
    let ctx = mode.pick(64, 256, 256);

    let mechs = [
        ("softmax", "softmax"),
        ("poly (p=4)", "poly4"),
        ("psk r=16 + local", "psk4_r16_b32_local"),
    ];

    let mut table = Table::new(
        &format!("Table 5 analog — selective copying token accuracy % after {steps} steps (ctx {ctx})"),
        "mechanism",
        vec!["token %".into(), "steps to >50% token".into()],
    );
    let mut records: Vec<Record> = Vec::new();

    for (label, mech_label) in mechs {
        let task = SelectiveCopyTask::standard(ctx);
        let mech = Mechanism::parse(mech_label).expect("bench mechanism");
        let mut model = NativeLm::new(
            LmConfig {
                vocab: task.vocab(),
                d_model: 64,
                layers: 2,
                heads: 4,
                seed: 0,
                ..LmConfig::default()
            },
            mech,
        );
        let cfg = TrainConfig {
            steps,
            batch: 16,
            optim: OptimConfig { lr: 3e-3, warmup: 20, total_steps: steps, ..Default::default() },
            seed: 0,
            eval_every: (steps / 10).max(1),
            eval_examples,
            stop_at_accuracy: 0.995,
            echo_every: 0,
            log_path: None,
            ckpt_path: None,
            ckpt_every: 0,
        };
        let summary = Trainer::new(&mut model, TrainSource::Copy(task), cfg).run()?;

        // Figure 5: the accuracy-vs-steps curve (sudden learning).
        println!("\n{label} accuracy curve (Figure 5 analog):");
        for pt in &summary.curve {
            println!(
                "  step {:>6}  token {:>6.1}%  (loss {:.4})",
                pt.step,
                pt.accuracy * 100.0,
                pt.loss
            );
            records.push(
                Record::new()
                    .str("mech", mech_label)
                    .i64("step", pt.step as i64)
                    .f64("token_accuracy", pt.accuracy)
                    .f64("loss", pt.loss),
            );
        }
        let jump = summary
            .curve
            .iter()
            .find(|pt| pt.accuracy > 0.5)
            .map(|pt| pt.step.to_string())
            .unwrap_or_else(|| "-".into());
        table.row(label, vec![format!("{:.1}", summary.final_accuracy * 100.0), jump]);
        println!("{label} done ({} steps in {:.1}s)\n", summary.steps_run, summary.wall_secs);
    }
    print!("{}", table.render());
    println!("csv: {}", table.save_csv("table5_selective_copy")?.display());

    let json_path = write_json(
        "table5_selective_copy",
        &[("mode", format!("\"{mode:?}\"")), ("ctx", format!("{ctx}"))],
        &records,
    )?;
    println!("json: {}", json_path.display());
    Ok(())
}
