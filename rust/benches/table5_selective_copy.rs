//! Table 5 / Figure 5 — Selective Copying accuracy per attention mechanism.
//!
//! The paper trains 2-layer models (8 heads x 16) on the selective copying
//! task at ctx 4k/16k/32k and reports exact-match accuracy, observing a
//! sudden accuracy jump during training (Figure 5).  Scaled here: the
//! Appendix-F task artifacts at ctx 256, softmax vs poly(4) vs polysketch
//! (learned + local), with the accuracy-over-steps curve printed per model.
//!
//! Expected shape (paper): all mechanisms learn the task to high accuracy
//! at in-budget context lengths, with a visible sudden-learning jump.

use polysketchformer::bench::{banner, Mode, Table};
use polysketchformer::coordinator::{run_task, TaskRunnerConfig};
use polysketchformer::runtime::{self, LoadOpts};
use polysketchformer::tasks::selective_copy::SelectiveCopyTask;

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("table5_selective_copy", "Table 5 + Figure 5 (accuracy curve)", mode);
    let steps = mode.pick(10, 200, 2500);
    let eval_examples = mode.pick(16, 64, 256);

    let artifacts = [
        ("softmax", "copy_softmax"),
        ("poly (p=4)", "copy_poly4"),
        ("psk learned+local r16", "copy_psk"),
    ];

    let mut table = Table::new(
        &format!("Table 5 analog — selective copying exact-match % after {steps} steps (ctx 256)"),
        "mechanism",
        vec!["exact %".into(), "token %".into(), "steps to >50% token".into()],
    );

    for (label, name) in artifacts {
        let mut model = match runtime::load_model(name, LoadOpts::default()) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("  [skip {name}: {e}]");
                table.row(label, vec!["-".into(), "-".into()]);
                continue;
            }
        };
        let task = SelectiveCopyTask::standard(model.ctx());
        let cfg = TaskRunnerConfig {
            steps,
            eval_every: (steps / 10).max(1),
            eval_examples,
            echo_every: 0,
            seed: 0,
            stop_at_accuracy: 0.995,
        };
        let summary = run_task(&mut model, &task, &cfg)?;

        // Figure 5: the accuracy-vs-steps curve (sudden learning).
        println!("\n{label} accuracy curve (Figure 5 analog):");
        for &(step, acc) in &summary.curve {
            println!(
                "  step {step:>6}  exact {:>6.1}%  token {:>6.1}%",
                acc.exact * 100.0,
                acc.token * 100.0
            );
        }
        let jump = summary
            .curve
            .iter()
            .find(|&&(_, a)| a.token > 0.5)
            .map(|&(s, _)| s.to_string())
            .unwrap_or_else(|| "-".into());
        table.row(
            label,
            vec![
                format!("{:.1}", summary.final_accuracy.exact * 100.0),
                format!("{:.1}", summary.final_accuracy.token * 100.0),
                jump,
            ],
        );
        println!("{label} done\n");
    }
    print!("{}", table.render());
    println!("csv: {}", table.save_csv("table5_selective_copy")?.display());
    Ok(())
}
