//! Train-step throughput vs context length — the native analogue of the
//! paper's Table 4 training-speedup measurement.
//!
//! Times one full training step (forward tape + backward through the
//! kernel core + AdamW update) per (mechanism, context) cell and writes
//! `bench_out/train_throughput.json`.  The paper's claim is that the
//! sketched mechanism's step time grows ~linearly in context while the
//! softmax family grows quadratically; the bench prints per-mechanism
//! growth ratios (time at ctx vs time at ctx/2) so the sub-quadratic
//! separation — and the crossover point — is visible directly in the
//! artifact.
//!
//! In quick/full modes (TRAIN_THROUGHPUT_CHECK also forces it) the bench
//! fails if, at the largest context both families ran, the polysketch
//! step is not faster than the softmax step — the minimal "crossover
//! visible" gate.  Smoke mode prints the comparison but only enforces it
//! under the env var, because sub-second smoke shapes sit inside timer
//! noise on shared runners.

use std::fmt::Write as _;

use polysketchformer::attn::Mechanism;
use polysketchformer::bench::{banner, time_fn, write_json, Mode, Table};
use polysketchformer::infer::{LmConfig, NativeLm};
use polysketchformer::metrics::Record;
use polysketchformer::train::{compute_grads, AdamW, OptimConfig, TrainExample};

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("train_throughput", "Table 4 analog (train-step seconds vs context)", mode);

    let mech_labels = ["softmax", "flash_b256", "psk4_r16_b64_local"];
    let ctxs: Vec<usize> = match mode {
        Mode::Smoke => vec![256, 512],
        Mode::Quick => vec![512, 1024, 2048, 4096],
        Mode::Full => vec![1024, 2048, 4096, 8192, 16_384, 32_768],
    };
    // The quadratic backward at 32k is minutes of wall time; cap it the
    // way fig1/decode_throughput cap their quadratic prefill cells.
    let quad_cap = mode.pick(usize::MAX, 4096, 8192);
    let iters = mode.pick(1, 2, 2);

    let cfg = LmConfig { vocab: 257, d_model: 64, layers: 2, heads: 4, ..LmConfig::default() };
    let mut table = Table::new(
        &format!("train-step seconds vs context (d=64 L=2 H=4, batch 1, {iters} iters)"),
        "mechanism",
        ctxs.iter().map(|c| format!("{c}")).collect(),
    );
    let mut records: Vec<Record> = Vec::new();
    // secs[mech][ctx_idx], NaN when capped out.
    let mut secs = vec![vec![f64::NAN; ctxs.len()]; mech_labels.len()];

    for (mi, label) in mech_labels.iter().enumerate() {
        let mech = Mechanism::parse(label).expect("bench mechanism");
        let mut cells: Vec<String> = Vec::new();
        for (ci, &ctx) in ctxs.iter().enumerate() {
            if !mech.is_linear() && ctx > quad_cap {
                cells.push("capped".into());
                continue;
            }
            let mut model = NativeLm::new(cfg.clone(), mech.clone());
            let mut opt = AdamW::new(
                OptimConfig { total_steps: 16, warmup: 0, ..OptimConfig::default() },
                model.params(),
            );
            let tokens: Vec<u32> =
                (0..=ctx as u32).map(|i| i.wrapping_mul(2654435761) % 257).collect();
            let ex = TrainExample { tokens, mask: vec![true; ctx] };
            let batch = [ex];
            let t = time_fn(1, iters, || {
                let (grads, stats) = compute_grads(&model, &batch);
                assert!(stats.loss.is_finite(), "{label} ctx {ctx}: non-finite loss");
                opt.step(model.params_mut(), &grads);
            });
            secs[mi][ci] = t.mean_s;
            cells.push(format!("{:.3}s", t.mean_s));
            records.push(
                Record::new()
                    .str("mech", *label)
                    .i64("ctx", ctx as i64)
                    .f64("step_secs", t.mean_s)
                    .f64("tokens_per_sec", ctx as f64 / t.mean_s),
            );
            println!("{label:<20} ctx {ctx:>6}: {:.3}s/step", t.mean_s);
        }
        table.row(label, cells);
    }

    print!("{}", table.render());
    println!("csv: {}", table.save_csv("train_throughput")?.display());

    // Growth ratios: time(ctx) / time(ctx/2) — ~2 is linear, ~4 quadratic.
    println!("\ngrowth ratios (step time at ctx vs previous swept ctx):");
    for (mi, label) in mech_labels.iter().enumerate() {
        let mut line = format!("  {label:<20}");
        for ci in 1..ctxs.len() {
            let (a, b) = (secs[mi][ci - 1], secs[mi][ci]);
            if a.is_finite() && b.is_finite() && a > 0.0 {
                let _ = write!(line, "  x{:.2}", b / a);
            } else {
                let _ = write!(line, "  -");
            }
        }
        println!("{line}");
    }

    // Crossover gate at the largest context every mechanism completed.
    let psk = mech_labels.iter().position(|l| l.starts_with("psk")).unwrap();
    let soft = mech_labels.iter().position(|l| *l == "softmax").unwrap();
    let common = (0..ctxs.len())
        .rev()
        .find(|&ci| secs[psk][ci].is_finite() && secs[soft][ci].is_finite());
    let enforce = mode >= Mode::Quick || std::env::var_os("TRAIN_THROUGHPUT_CHECK").is_some();
    if let Some(ci) = common {
        let (ps, ss) = (secs[psk][ci], secs[soft][ci]);
        println!(
            "\nTRAIN_THROUGHPUT_CHECK: ctx {} — polysketch {:.3}s vs softmax {:.3}s",
            ctxs[ci], ps, ss
        );
        if enforce && ps >= ss {
            anyhow::bail!(
                "TRAIN_THROUGHPUT_CHECK fail: polysketch train step ({ps:.3}s) not faster \
                 than softmax ({ss:.3}s) at ctx {}",
                ctxs[ci]
            );
        }
    }

    let json_path = write_json(
        "train_throughput",
        &[
            ("mode", format!("\"{mode:?}\"")),
            (
                "model",
                format!(
                    "{{\"d_model\": {}, \"layers\": {}, \"heads\": {}, \"vocab\": {}}}",
                    cfg.d_model, cfg.layers, cfg.heads, cfg.vocab
                ),
            ),
        ],
        &records,
    )?;
    println!("json: {}", json_path.display());
    Ok(())
}
