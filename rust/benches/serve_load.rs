//! Serve load — closed-loop load generation against the serving gateway.
//!
//! Sweeps concurrency (clients = decode workers) × prompt-reuse ratio for
//! one linear mechanism and one softmax-family mechanism, driving the
//! in-process gateway lifecycle (admission -> prompt cache -> worker pool
//! -> token stream) with no HTTP in the measured path.  Two payoffs to
//! look for:
//!
//!   * cache-hit TTFT ≪ cold TTFT (the prefix cache erases prefill — the
//!     constant-size-state serving advantage);
//!   * p99 TTFT stays flat as concurrency grows for the linear mechanism
//!     while aggregate tokens/sec scales with workers.
//!
//! Results print as a table, persist as CSV, and land in
//! `bench_out/serve_load.json` for the cross-PR perf trajectory.

use std::collections::HashMap;
use std::fmt::Write as _;
use std::path::PathBuf;
use std::sync::Arc;

use polysketchformer::attn::Mechanism;
use polysketchformer::bench::{banner, out_dir, Mode, Table};
use polysketchformer::infer::{DecodeSession, GenRequest, LmConfig, NativeLm, SamplePolicy};
use polysketchformer::mem::quant::{self, QuantMode};
use polysketchformer::metrics::Record;
use polysketchformer::serve::cache::ENTRY_OVERHEAD_BYTES;
use polysketchformer::serve::{collect_stream, Gateway, GatewayConfig, PromptCache, RequestStats};
use polysketchformer::shard::{
    collect_shard_stream, ShardConfig, ShardGateway, Supervisor, SupervisorConfig,
};
use polysketchformer::util::rng::Pcg;
use polysketchformer::util::stats::percentile;

fn prompt(tag: u64, len: usize) -> Vec<u32> {
    std::iter::once(0u32)
        .chain((0..len as u64).map(|i| 1 + ((tag.wrapping_mul(2654435761) + i * 97) % 256) as u32))
        .collect()
}

fn pctl(mut xs: Vec<f64>, q: f64) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(percentile(&xs, q))
}

fn fmt_ms(v: Option<f64>) -> String {
    match v {
        Some(ms) => format!("{:.2}", ms * 1e3),
        None => "-".into(),
    }
}

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("serve_load", "serving gateway under closed-loop load (TTFT, throughput)", mode);

    let mech_labels = ["psk4_r16_b32_local", "softmax"];
    let concurrencies: Vec<usize> = match mode {
        Mode::Smoke => vec![1, 2],
        Mode::Quick => vec![1, 2, 4],
        Mode::Full => vec![1, 2, 4, 8],
    };
    let reuse_ratios = [0.0f64, 0.75];
    let prompt_len = mode.pick(48, 128, 256);
    let max_new = mode.pick(8, 16, 24);
    let reqs_per_client = mode.pick(4, 8, 16);
    // Small shared-prompt pool: high reuse means most requests replay one
    // of these and should hit the prefix cache after first touch.
    let shared_pool = 2u64;

    let mut table = Table::new(
        &format!(
            "serve load (prompt {prompt_len} tok, {max_new} new/req, {reqs_per_client} req/client)"
        ),
        "mech · clients · reuse",
        vec![
            "cold TTFT p50 ms".into(),
            "hit TTFT p50 ms".into(),
            "TTFT p99 ms".into(),
            "tok/s".into(),
            "hit rate".into(),
        ],
    );
    let mut records: Vec<Record> = Vec::new();

    for label in mech_labels {
        let mech = Mechanism::parse(label).expect("bench mechanism labels must parse");
        for &clients in &concurrencies {
            for &reuse in &reuse_ratios {
                let lm_cfg = LmConfig { d_model: 64, layers: 2, heads: 2, ..LmConfig::default() };
                let gateway = Arc::new(Gateway::new(
                    NativeLm::new(lm_cfg, mech.clone()),
                    GatewayConfig {
                        workers: clients,
                        queue_cap: 4 * clients.max(1) + 8,
                        max_resident: 2 * clients.max(1),
                        cache_bytes: 256 << 20,
                        ..GatewayConfig::default()
                    },
                )?);

                let t0 = std::time::Instant::now();
                let handles: Vec<_> = (0..clients)
                    .map(|ci| {
                        let gw = Arc::clone(&gateway);
                        std::thread::spawn(move || {
                            let mut rng = Pcg::new(0x10ad ^ ci as u64, ci as u64);
                            let mut stats: Vec<RequestStats> = Vec::new();
                            for j in 0..reqs_per_client {
                                let p = if rng.f64() < reuse {
                                    prompt(rng.below(shared_pool), prompt_len)
                                } else {
                                    prompt(1000 + (ci * 10_000 + j) as u64, prompt_len)
                                };
                                let req = GenRequest {
                                    prompt: p,
                                    max_new_tokens: max_new,
                                    policy: SamplePolicy::Greedy,
                                    seed: (ci * 1000 + j) as u64,
                                };
                                // Closed loop: next request only after this
                                // one fully streamed back.
                                if let Ok(rx) = gw.submit(req) {
                                    if let (_, Some(s)) = collect_stream(rx) {
                                        stats.push(s);
                                    }
                                }
                            }
                            stats
                        })
                    })
                    .collect();
                let all: Vec<RequestStats> =
                    handles.into_iter().flat_map(|h| h.join().expect("client panicked")).collect();
                let wall = t0.elapsed().as_secs_f64();
                gateway.finish()?;

                let total_tokens: usize = all.iter().map(|s| s.new_tokens).sum();
                let hits = all.iter().filter(|s| s.cache_hit).count();
                let cold_ttft: Vec<f64> =
                    all.iter().filter(|s| !s.cache_hit).map(|s| s.ttft_secs).collect();
                let hit_ttft: Vec<f64> =
                    all.iter().filter(|s| s.cache_hit).map(|s| s.ttft_secs).collect();
                let every_ttft: Vec<f64> = all.iter().map(|s| s.ttft_secs).collect();
                let tok_s = if wall > 0.0 { total_tokens as f64 / wall } else { 0.0 };
                let hit_rate = hits as f64 / all.len().max(1) as f64;

                let cold_p50 = pctl(cold_ttft.clone(), 50.0);
                let hit_p50 = pctl(hit_ttft.clone(), 50.0);
                let p99 = pctl(every_ttft, 99.0);
                table.row(
                    &format!("{label} · c{clients} · r{reuse:.2}"),
                    vec![
                        fmt_ms(cold_p50),
                        fmt_ms(hit_p50),
                        fmt_ms(p99),
                        format!("{tok_s:.1}"),
                        format!("{:.0}%", hit_rate * 100.0),
                    ],
                );
                records.push(
                    Record::new()
                        .str("mech", label)
                        .bool("linear", mech.is_linear())
                        .i64("clients", clients as i64)
                        .f64("reuse", reuse)
                        .i64("prompt_len", prompt_len as i64)
                        .i64("max_new", max_new as i64)
                        .i64("requests", all.len() as i64)
                        .i64("cache_hits", hits as i64)
                        .f64("hit_rate", hit_rate)
                        .f64("ttft_cold_p50_ms", cold_p50.map(|v| v * 1e3).unwrap_or(-1.0))
                        .f64("ttft_cold_p99_ms", pctl(cold_ttft, 99.0).map(|v| v * 1e3).unwrap_or(-1.0))
                        .f64("ttft_hit_p50_ms", hit_p50.map(|v| v * 1e3).unwrap_or(-1.0))
                        .f64("ttft_hit_p99_ms", pctl(hit_ttft, 99.0).map(|v| v * 1e3).unwrap_or(-1.0))
                        .f64("ttft_p99_ms", p99.map(|v| v * 1e3).unwrap_or(-1.0))
                        .f64("tokens_per_sec", tok_s)
                        .f64("wall_secs", wall),
                );
            }
        }
    }

    print!("{}", table.render());
    println!("csv: {}", table.save_csv("serve_load")?.display());

    // ---- runner sweep: multi-process sharded serving scaling ----------
    //
    // Same closed-loop clients, but the gateway routes over Unix-socket
    // IPC to `psf runner` worker processes (one exec-pool thread each, so
    // runner count — not thread count — is the compute knob).  The payoff
    // is data-parallel throughput scaling: 2 runners must beat 1 by at
    // least 1.5x.  Enforced when PSF_SERVE_SCALE_CHECK=1 (the CI bench
    // smoke sets it), advisory otherwise so loaded laptops don't fail.
    let sweep_clients: Vec<usize> = match mode {
        Mode::Smoke => vec![2],
        Mode::Quick | Mode::Full => vec![2, 8],
    };
    let sweep_reqs = mode.pick(3, 6, 10);
    let sweep_label = "psk4_r16_b32_local";
    let sweep_mech = Mechanism::parse(sweep_label).expect("bench mechanism labels must parse");
    let mut sweep_table = Table::new(
        &format!("runner sweep (sharded serving, {max_new} new/req, {sweep_reqs} req/client)"),
        "runners · clients",
        vec!["tok/s".into(), "requests".into(), "failed".into()],
    );
    let mut sweep_records: Vec<Record> = Vec::new();
    let mut tput: HashMap<(usize, usize), f64> = HashMap::new();

    for &runners in &[1usize, 2] {
        for &clients in &sweep_clients {
            let sup = Supervisor::start(SupervisorConfig {
                runners,
                runner_exe: PathBuf::from(env!("CARGO_BIN_EXE_psf")),
                model_args: vec![
                    "--mech".into(),
                    sweep_label.into(),
                    "--d-model".into(),
                    "64".into(),
                    "--layers".into(),
                    "2".into(),
                    "--heads".into(),
                    "2".into(),
                    "--seed".into(),
                    "0".into(),
                ],
                runner_workers: 2,
                threads_per_runner: 1,
                ..SupervisorConfig::default()
            })?;
            let gw = Arc::new(ShardGateway::new(sup, sweep_mech.clone(), ShardConfig::default())?);

            let t0 = std::time::Instant::now();
            let handles: Vec<_> = (0..clients)
                .map(|ci| {
                    let gw = Arc::clone(&gw);
                    std::thread::spawn(move || {
                        let (mut tokens, mut failed) = (0usize, 0usize);
                        for j in 0..sweep_reqs {
                            // Distinct prompts: spread the hash ring, so
                            // every runner's cache slice stays in play.
                            let req = GenRequest {
                                prompt: prompt(7_000 + (ci * 1_000 + j) as u64, 32),
                                max_new_tokens: max_new,
                                policy: SamplePolicy::Greedy,
                                seed: (ci * 31 + j) as u64,
                            };
                            match gw.submit(req) {
                                Ok(rx) => {
                                    let reply = collect_shard_stream(rx);
                                    tokens += reply.tokens.len();
                                    if reply.done.is_none() {
                                        failed += 1;
                                    }
                                }
                                Err(_) => failed += 1,
                            }
                        }
                        (tokens, failed)
                    })
                })
                .collect();
            let (mut total_tokens, mut total_failed) = (0usize, 0usize);
            for h in handles {
                let (t, f) = h.join().expect("sweep client panicked");
                total_tokens += t;
                total_failed += f;
            }
            let wall = t0.elapsed().as_secs_f64();
            gw.finish()?;

            anyhow::ensure!(
                total_failed == 0,
                "runner sweep had {total_failed} failed requests ({runners} runners, {clients} clients)"
            );
            let tok_s = if wall > 0.0 { total_tokens as f64 / wall } else { 0.0 };
            tput.insert((runners, clients), tok_s);
            sweep_table.row(
                &format!("{runners} · c{clients}"),
                vec![
                    format!("{tok_s:.1}"),
                    format!("{}", clients * sweep_reqs),
                    format!("{total_failed}"),
                ],
            );
            sweep_records.push(
                Record::new()
                    .str("mech", sweep_label)
                    .i64("runners", runners as i64)
                    .i64("clients", clients as i64)
                    .i64("requests", (clients * sweep_reqs) as i64)
                    .i64("failed", total_failed as i64)
                    .f64("tokens_per_sec", tok_s)
                    .f64("wall_secs", wall),
            );
        }
    }

    print!("{}", sweep_table.render());
    let enforce = std::env::var("PSF_SERVE_SCALE_CHECK").ok().as_deref() == Some("1");
    for &clients in &sweep_clients {
        let t1 = tput[&(1, clients)];
        let t2 = tput[&(2, clients)];
        let speedup = if t1 > 0.0 { t2 / t1 } else { 0.0 };
        println!(
            "runner scaling @ c{clients}: 1 runner {t1:.1} tok/s -> 2 runners {t2:.1} tok/s \
             ({speedup:.2}x)"
        );
        if enforce {
            anyhow::ensure!(
                speedup >= 1.5,
                "2-runner throughput {t2:.1} tok/s < 1.5x 1-runner {t1:.1} tok/s at \
                 concurrency {clients}"
            );
        } else if speedup < 1.5 {
            println!("  advisory: below the 1.5x target (PSF_SERVE_SCALE_CHECK=1 enforces)");
        }
    }

    // ---- tracing-overhead A/B -----------------------------------------
    //
    // Same closed-loop load twice — obs spans+phases off, then on — to
    // check the observability hooks stay near-free.  Tracing can never
    // change output bytes (tests/obs_trace.rs pins that); this pins the
    // wall-clock side of the overhead contract.  Enforced when
    // PSF_OBS_OVERHEAD_CHECK=1 (the CI bench smoke sets it), advisory
    // otherwise so loaded laptops don't fail.
    let overhead_reqs = mode.pick(3, 6, 10);
    let overhead_load = |on: bool| -> anyhow::Result<f64> {
        polysketchformer::obs::set_tracing(on);
        polysketchformer::obs::set_phases(on);
        let lm_cfg = LmConfig { d_model: 64, layers: 2, heads: 2, ..LmConfig::default() };
        let gateway = Arc::new(Gateway::new(
            NativeLm::new(lm_cfg, Mechanism::parse("psk4_r16_b32_local").unwrap()),
            GatewayConfig {
                workers: 2,
                queue_cap: 64,
                max_resident: 4,
                cache_bytes: 64 << 20,
                ..GatewayConfig::default()
            },
        )?);
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..2usize)
            .map(|ci| {
                let gw = Arc::clone(&gateway);
                std::thread::spawn(move || {
                    let mut tokens = 0usize;
                    for j in 0..overhead_reqs {
                        let req = GenRequest {
                            prompt: prompt(40_000 + (ci * 100 + j) as u64, prompt_len),
                            max_new_tokens: max_new,
                            policy: SamplePolicy::Greedy,
                            seed: (ci * 17 + j) as u64,
                        };
                        if let Ok(rx) = gw.submit(req) {
                            let (toks, _) = collect_stream(rx);
                            tokens += toks.len();
                        }
                    }
                    tokens
                })
            })
            .collect();
        let total: usize =
            handles.into_iter().map(|h| h.join().expect("overhead client panicked")).sum();
        let wall = t0.elapsed().as_secs_f64();
        gateway.finish()?;
        polysketchformer::obs::set_tracing(false);
        polysketchformer::obs::set_phases(false);
        Ok(if wall > 0.0 { total as f64 / wall } else { 0.0 })
    };
    let off_tok_s = overhead_load(false)?;
    let on_tok_s = overhead_load(true)?;
    let retained = if off_tok_s > 0.0 { on_tok_s / off_tok_s } else { 1.0 };
    println!(
        "tracing overhead: off {off_tok_s:.1} tok/s -> on {on_tok_s:.1} tok/s \
         ({:.0}% retained)",
        retained * 100.0
    );
    if std::env::var("PSF_OBS_OVERHEAD_CHECK").ok().as_deref() == Some("1") {
        anyhow::ensure!(
            on_tok_s >= 0.5 * off_tok_s,
            "tracing-on throughput {on_tok_s:.1} tok/s fell below half of tracing-off \
             {off_tok_s:.1} tok/s — the obs hooks are no longer near-free"
        );
    } else if retained < 0.5 {
        println!("  advisory: below the 50% floor (PSF_OBS_OVERHEAD_CHECK=1 enforces)");
    }

    // ---- sentinel-overhead A/B ----------------------------------------
    //
    // Same A/B for the numeric-health sentinels: sampled absmax scans at
    // kernel boundaries must not tax serving.  tests/sentinel.rs pins
    // that outputs are byte-identical on/off; this pins the wall clock,
    // under the same PSF_OBS_OVERHEAD_CHECK=1 gate.
    let sentinel_load = |on: bool| -> anyhow::Result<f64> {
        polysketchformer::obs::set_sentinels(on);
        polysketchformer::obs::sentinel::reset();
        let lm_cfg = LmConfig { d_model: 64, layers: 2, heads: 2, ..LmConfig::default() };
        let gateway = Arc::new(Gateway::new(
            NativeLm::new(lm_cfg, Mechanism::parse("psk4_r16_b32_local").unwrap()),
            GatewayConfig {
                workers: 2,
                queue_cap: 64,
                max_resident: 4,
                cache_bytes: 64 << 20,
                ..GatewayConfig::default()
            },
        )?);
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..2usize)
            .map(|ci| {
                let gw = Arc::clone(&gateway);
                std::thread::spawn(move || {
                    let mut tokens = 0usize;
                    for j in 0..overhead_reqs {
                        let req = GenRequest {
                            prompt: prompt(60_000 + (ci * 100 + j) as u64, prompt_len),
                            max_new_tokens: max_new,
                            policy: SamplePolicy::Greedy,
                            seed: (ci * 23 + j) as u64,
                        };
                        if let Ok(rx) = gw.submit(req) {
                            let (toks, _) = collect_stream(rx);
                            tokens += toks.len();
                        }
                    }
                    tokens
                })
            })
            .collect();
        let total: usize =
            handles.into_iter().map(|h| h.join().expect("sentinel client panicked")).sum();
        let wall = t0.elapsed().as_secs_f64();
        gateway.finish()?;
        polysketchformer::obs::set_sentinels(false);
        polysketchformer::obs::sentinel::reset();
        Ok(if wall > 0.0 { total as f64 / wall } else { 0.0 })
    };
    let sent_off_tok_s = sentinel_load(false)?;
    let sent_on_tok_s = sentinel_load(true)?;
    let sent_retained = if sent_off_tok_s > 0.0 { sent_on_tok_s / sent_off_tok_s } else { 1.0 };
    println!(
        "sentinel overhead: off {sent_off_tok_s:.1} tok/s -> on {sent_on_tok_s:.1} tok/s \
         ({:.0}% retained)",
        sent_retained * 100.0
    );
    if std::env::var("PSF_OBS_OVERHEAD_CHECK").ok().as_deref() == Some("1") {
        anyhow::ensure!(
            sent_on_tok_s >= 0.5 * sent_off_tok_s,
            "sentinel-on throughput {sent_on_tok_s:.1} tok/s fell below half of sentinel-off \
             {sent_off_tok_s:.1} tok/s — the sampled scans are too hot"
        );
    } else if sent_retained < 0.5 {
        println!("  advisory: below the 50% floor (PSF_OBS_OVERHEAD_CHECK=1 enforces)");
    }

    // ---- memory sweep: frozen sessions per GB across storage tiers ----
    //
    // Freezes a prefilled prompt-prefix under the exact (f32) and compact
    // (f16) cold tiers and converts the measured per-entry footprint into
    // cached-sessions-per-GB at 1k/10k-session fleet sizes, plus the TTFT
    // split (cold prefill vs thaw-from-cache).  Sub-block prompts
    // (shorter than the mechanism block: tail-only images, Z elided) are
    // the gated points — the compact tier must hold >= 3x the sessions of
    // f32 there when PSF_MEM_CHECK=1 (the CI bench smoke sets it);
    // block-crossing prompts carry the dense Z moments and are reported
    // ungated (f16 approaches its plain 2x there by construction).
    let mem_check = std::env::var("PSF_MEM_CHECK").ok().as_deref() == Some("1");
    let mem_label = "psk4_r16_b32_local";
    let mem_mech = Mechanism::parse(mem_label).expect("bench mechanism labels must parse");
    // (tag, prompt tokens after BOS, gated): totals 24 and 31 stay inside
    // the 32-block; 91 crosses it twice.
    let mem_points: &[(&str, usize, bool)] =
        &[("subblock", 23, true), ("subblock", 30, true), ("z+tail", 90, false)];
    let mut mem_records: Vec<Record> = Vec::new();
    let mut mem_table = Table::new(
        "memory sweep (frozen prompt-prefix entries, f32 vs f16 cold tier)",
        "point · prompt",
        vec![
            "f32 B/entry".into(),
            "f16 B/entry".into(),
            "ratio".into(),
            "f16 sess/GB".into(),
            "GB @ 10k".into(),
            "cold TTFT ms".into(),
            "thaw ms".into(),
        ],
    );
    for &(tag, plen, gated) in mem_points {
        let lm_cfg = LmConfig { d_model: 64, layers: 2, heads: 2, ..LmConfig::default() };
        let m = NativeLm::new(lm_cfg, mem_mech.clone());
        let p = prompt(77, plen);
        let zero_req = || GenRequest {
            prompt: p.clone(),
            max_new_tokens: 0,
            policy: SamplePolicy::Greedy,
            seed: 0,
        };
        // Cold TTFT proxy: the prefill a cache hit erases (best of 3).
        let cold_secs = (0..3)
            .map(|_| {
                let t = std::time::Instant::now();
                let _ = DecodeSession::new(&m, 0, zero_req());
                t.elapsed().as_secs_f64()
            })
            .fold(f64::INFINITY, f64::min);
        let entry_bytes = |qm: QuantMode| -> usize {
            quant::force_mode(qm);
            let cache = PromptCache::new(1 << 30);
            let snap = cache.freeze(&DecodeSession::new(&m, 0, zero_req()));
            let b = snap.bytes() + p.len() * 4 + ENTRY_OVERHEAD_BYTES;
            quant::reset_mode();
            b
        };
        let f32_entry = entry_bytes(QuantMode::Off);
        let f16_entry = entry_bytes(QuantMode::F16);
        // Thaw latency of the compact tier (what a hit pays instead).
        quant::force_mode(QuantMode::F16);
        let cache = PromptCache::new(1 << 30);
        let snap = cache.freeze(&DecodeSession::new(&m, 0, zero_req()));
        let thaw_secs = (0..3)
            .map(|_| {
                let t = std::time::Instant::now();
                let (states, logits) = snap.thaw(&m);
                let _ = DecodeSession::from_prefix(1, zero_req(), states, logits);
                t.elapsed().as_secs_f64()
            })
            .fold(f64::INFINITY, f64::min);
        quant::reset_mode();

        let ratio = f32_entry as f64 / f16_entry as f64;
        let f16_per_gb = (1u64 << 30) as f64 / f16_entry as f64;
        let gb_at = |sessions: f64, entry: usize| sessions * entry as f64 / (1u64 << 30) as f64;
        mem_table.row(
            &format!("{tag} · {} tok", p.len()),
            vec![
                format!("{f32_entry}"),
                format!("{f16_entry}"),
                format!("{ratio:.2}x"),
                format!("{f16_per_gb:.0}"),
                format!("{:.3}", gb_at(10_000.0, f16_entry)),
                format!("{:.2}", cold_secs * 1e3),
                format!("{:.2}", thaw_secs * 1e3),
            ],
        );
        mem_records.push(
            Record::new()
                .str("mech", mem_label)
                .str("point", tag)
                .bool("gated", gated)
                .i64("prompt_len", p.len() as i64)
                .i64("f32_entry_bytes", f32_entry as i64)
                .i64("f16_entry_bytes", f16_entry as i64)
                .f64("ratio", ratio)
                .f64("f32_sessions_per_gb", (1u64 << 30) as f64 / f32_entry as f64)
                .f64("f16_sessions_per_gb", f16_per_gb)
                .f64("gb_at_1k_f16", gb_at(1_000.0, f16_entry))
                .f64("gb_at_10k_f16", gb_at(10_000.0, f16_entry))
                .f64("gb_at_10k_f32", gb_at(10_000.0, f32_entry))
                .f64("cold_ttft_ms", cold_secs * 1e3)
                .f64("thaw_ms", thaw_secs * 1e3),
        );
        if gated {
            if mem_check {
                anyhow::ensure!(
                    ratio >= 3.0,
                    "f16 tier holds only {ratio:.2}x the sessions of f32 at {tag} \
                     prompt {} (< 3x floor)",
                    p.len()
                );
            } else if ratio < 3.0 {
                println!(
                    "  advisory: {tag} prompt {} ratio {ratio:.2}x below the 3x floor \
                     (PSF_MEM_CHECK=1 enforces)",
                    p.len()
                );
            }
        }
    }
    print!("{}", mem_table.render());

    // q8 weights vs f32 on the single-token decode path (where weight
    // bandwidth dominates): the int8 twins must retain >= 0.9x of f32
    // decode throughput when PSF_MEM_CHECK=1.
    let q8_steps = mode.pick(48, 160, 320);
    let decode_tok_s = |qm: QuantMode| -> f64 {
        quant::force_mode(qm);
        let lm_cfg = LmConfig { d_model: 64, layers: 2, heads: 2, ..LmConfig::default() };
        let mut m = NativeLm::new(lm_cfg, mem_mech.clone());
        m.requantize();
        let mut s = DecodeSession::new(
            &m,
            0,
            GenRequest {
                prompt: prompt(5, 32),
                max_new_tokens: q8_steps,
                policy: SamplePolicy::Greedy,
                seed: 1,
            },
        );
        let t0 = std::time::Instant::now();
        s.run_to_completion(&m);
        let wall = t0.elapsed().as_secs_f64();
        quant::reset_mode();
        if wall > 0.0 {
            q8_steps as f64 / wall
        } else {
            0.0
        }
    };
    let f32_decode = decode_tok_s(QuantMode::Off);
    let q8_decode = decode_tok_s(QuantMode::Q8);
    let q8_retained = if f32_decode > 0.0 { q8_decode / f32_decode } else { 1.0 };
    println!(
        "q8 decode: f32 {f32_decode:.1} tok/s -> q8 {q8_decode:.1} tok/s \
         ({:.0}% retained)",
        q8_retained * 100.0
    );
    if mem_check {
        anyhow::ensure!(
            q8_retained >= 0.9,
            "q8 decode throughput {q8_decode:.1} tok/s < 0.9x f32 {f32_decode:.1} tok/s"
        );
    } else if q8_retained < 0.9 {
        println!("  advisory: below the 0.9x floor (PSF_MEM_CHECK=1 enforces)");
    }

    // JSON artifact, assembled with the same hand-rolled encoder the
    // metrics substrate uses (no serde in this environment).
    let mut json = String::from("{\n  \"bench\": \"serve_load\",\n");
    let _ = writeln!(json, "  \"mode\": \"{mode:?}\",");
    let _ = writeln!(
        json,
        "  \"load\": {{\"prompt_len\": {prompt_len}, \"max_new\": {max_new}, \
         \"reqs_per_client\": {reqs_per_client}}},"
    );
    json.push_str("  \"results\": [\n");
    for (i, r) in records.iter().enumerate() {
        let _ = write!(json, "    {}", r.to_json());
        json.push_str(if i + 1 < records.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ],\n  \"runner_sweep\": [\n");
    for (i, r) in sweep_records.iter().enumerate() {
        let _ = write!(json, "    {}", r.to_json());
        json.push_str(if i + 1 < sweep_records.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ],\n");
    let _ = writeln!(
        json,
        "  \"obs_overhead\": {{\"off_tok_s\": {off_tok_s:.3}, \"on_tok_s\": {on_tok_s:.3}, \
         \"retained\": {retained:.4}}},"
    );
    let _ = writeln!(
        json,
        "  \"sentinel_overhead\": {{\"off_tok_s\": {sent_off_tok_s:.3}, \
         \"on_tok_s\": {sent_on_tok_s:.3}, \"retained\": {sent_retained:.4}}},"
    );
    json.push_str("  \"mem_sweep\": [\n");
    for (i, r) in mem_records.iter().enumerate() {
        let _ = write!(json, "    {}", r.to_json());
        json.push_str(if i + 1 < mem_records.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ],\n");
    let _ = writeln!(
        json,
        "  \"q8_decode\": {{\"f32_tok_s\": {f32_decode:.3}, \"q8_tok_s\": {q8_decode:.3}, \
         \"retained\": {q8_retained:.4}}}"
    );
    json.push('}');
    json.push('\n');
    let dir = out_dir();
    std::fs::create_dir_all(&dir)?;
    let json_path = dir.join("serve_load.json");
    std::fs::write(&json_path, json)?;
    println!("json: {}", json_path.display());
    Ok(())
}
