//! Serve load — closed-loop load generation against the serving gateway.
//!
//! Sweeps concurrency (clients = decode workers) × prompt-reuse ratio for
//! one linear mechanism and one softmax-family mechanism, driving the
//! in-process gateway lifecycle (admission -> prompt cache -> worker pool
//! -> token stream) with no HTTP in the measured path.  Two payoffs to
//! look for:
//!
//!   * cache-hit TTFT ≪ cold TTFT (the prefix cache erases prefill — the
//!     constant-size-state serving advantage);
//!   * p99 TTFT stays flat as concurrency grows for the linear mechanism
//!     while aggregate tokens/sec scales with workers.
//!
//! Results print as a table, persist as CSV, and land in
//! `bench_out/serve_load.json` for the cross-PR perf trajectory.

use std::fmt::Write as _;
use std::sync::Arc;

use polysketchformer::attn::Mechanism;
use polysketchformer::bench::{banner, out_dir, Mode, Table};
use polysketchformer::infer::{GenRequest, LmConfig, NativeLm, SamplePolicy};
use polysketchformer::metrics::Record;
use polysketchformer::serve::{collect_stream, Gateway, GatewayConfig, RequestStats};
use polysketchformer::util::rng::Pcg;
use polysketchformer::util::stats::percentile;

fn prompt(tag: u64, len: usize) -> Vec<u32> {
    std::iter::once(0u32)
        .chain((0..len as u64).map(|i| 1 + ((tag.wrapping_mul(2654435761) + i * 97) % 256) as u32))
        .collect()
}

fn pctl(mut xs: Vec<f64>, q: f64) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(percentile(&xs, q))
}

fn fmt_ms(v: Option<f64>) -> String {
    match v {
        Some(ms) => format!("{:.2}", ms * 1e3),
        None => "-".into(),
    }
}

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("serve_load", "serving gateway under closed-loop load (TTFT, throughput)", mode);

    let mech_labels = ["psk4_r16_b32_local", "softmax"];
    let concurrencies: Vec<usize> = match mode {
        Mode::Smoke => vec![1, 2],
        Mode::Quick => vec![1, 2, 4],
        Mode::Full => vec![1, 2, 4, 8],
    };
    let reuse_ratios = [0.0f64, 0.75];
    let prompt_len = mode.pick(48, 128, 256);
    let max_new = mode.pick(8, 16, 24);
    let reqs_per_client = mode.pick(4, 8, 16);
    // Small shared-prompt pool: high reuse means most requests replay one
    // of these and should hit the prefix cache after first touch.
    let shared_pool = 2u64;

    let mut table = Table::new(
        &format!(
            "serve load (prompt {prompt_len} tok, {max_new} new/req, {reqs_per_client} req/client)"
        ),
        "mech · clients · reuse",
        vec![
            "cold TTFT p50 ms".into(),
            "hit TTFT p50 ms".into(),
            "TTFT p99 ms".into(),
            "tok/s".into(),
            "hit rate".into(),
        ],
    );
    let mut records: Vec<Record> = Vec::new();

    for label in mech_labels {
        let mech = Mechanism::parse(label).expect("bench mechanism labels must parse");
        for &clients in &concurrencies {
            for &reuse in &reuse_ratios {
                let lm_cfg = LmConfig { d_model: 64, layers: 2, heads: 2, ..LmConfig::default() };
                let gateway = Arc::new(Gateway::new(
                    NativeLm::new(lm_cfg, mech.clone()),
                    GatewayConfig {
                        workers: clients,
                        queue_cap: 4 * clients.max(1) + 8,
                        max_resident: 2 * clients.max(1),
                        cache_bytes: 256 << 20,
                        ..GatewayConfig::default()
                    },
                )?);

                let t0 = std::time::Instant::now();
                let handles: Vec<_> = (0..clients)
                    .map(|ci| {
                        let gw = Arc::clone(&gateway);
                        std::thread::spawn(move || {
                            let mut rng = Pcg::new(0x10ad ^ ci as u64, ci as u64);
                            let mut stats: Vec<RequestStats> = Vec::new();
                            for j in 0..reqs_per_client {
                                let p = if rng.f64() < reuse {
                                    prompt(rng.below(shared_pool), prompt_len)
                                } else {
                                    prompt(1000 + (ci * 10_000 + j) as u64, prompt_len)
                                };
                                let req = GenRequest {
                                    prompt: p,
                                    max_new_tokens: max_new,
                                    policy: SamplePolicy::Greedy,
                                    seed: (ci * 1000 + j) as u64,
                                };
                                // Closed loop: next request only after this
                                // one fully streamed back.
                                if let Ok(rx) = gw.submit(req) {
                                    if let (_, Some(s)) = collect_stream(rx) {
                                        stats.push(s);
                                    }
                                }
                            }
                            stats
                        })
                    })
                    .collect();
                let all: Vec<RequestStats> =
                    handles.into_iter().flat_map(|h| h.join().expect("client panicked")).collect();
                let wall = t0.elapsed().as_secs_f64();
                gateway.finish()?;

                let total_tokens: usize = all.iter().map(|s| s.new_tokens).sum();
                let hits = all.iter().filter(|s| s.cache_hit).count();
                let cold_ttft: Vec<f64> =
                    all.iter().filter(|s| !s.cache_hit).map(|s| s.ttft_secs).collect();
                let hit_ttft: Vec<f64> =
                    all.iter().filter(|s| s.cache_hit).map(|s| s.ttft_secs).collect();
                let every_ttft: Vec<f64> = all.iter().map(|s| s.ttft_secs).collect();
                let tok_s = if wall > 0.0 { total_tokens as f64 / wall } else { 0.0 };
                let hit_rate = hits as f64 / all.len().max(1) as f64;

                let cold_p50 = pctl(cold_ttft.clone(), 50.0);
                let hit_p50 = pctl(hit_ttft.clone(), 50.0);
                let p99 = pctl(every_ttft, 99.0);
                table.row(
                    &format!("{label} · c{clients} · r{reuse:.2}"),
                    vec![
                        fmt_ms(cold_p50),
                        fmt_ms(hit_p50),
                        fmt_ms(p99),
                        format!("{tok_s:.1}"),
                        format!("{:.0}%", hit_rate * 100.0),
                    ],
                );
                records.push(
                    Record::new()
                        .str("mech", label)
                        .bool("linear", mech.is_linear())
                        .i64("clients", clients as i64)
                        .f64("reuse", reuse)
                        .i64("prompt_len", prompt_len as i64)
                        .i64("max_new", max_new as i64)
                        .i64("requests", all.len() as i64)
                        .i64("cache_hits", hits as i64)
                        .f64("hit_rate", hit_rate)
                        .f64("ttft_cold_p50_ms", cold_p50.map(|v| v * 1e3).unwrap_or(-1.0))
                        .f64("ttft_cold_p99_ms", pctl(cold_ttft, 99.0).map(|v| v * 1e3).unwrap_or(-1.0))
                        .f64("ttft_hit_p50_ms", hit_p50.map(|v| v * 1e3).unwrap_or(-1.0))
                        .f64("ttft_hit_p99_ms", pctl(hit_ttft, 99.0).map(|v| v * 1e3).unwrap_or(-1.0))
                        .f64("ttft_p99_ms", p99.map(|v| v * 1e3).unwrap_or(-1.0))
                        .f64("tokens_per_sec", tok_s)
                        .f64("wall_secs", wall),
                );
            }
        }
    }

    print!("{}", table.render());
    println!("csv: {}", table.save_csv("serve_load")?.display());

    // JSON artifact, assembled with the same hand-rolled encoder the
    // metrics substrate uses (no serde in this environment).
    let mut json = String::from("{\n  \"bench\": \"serve_load\",\n");
    let _ = writeln!(json, "  \"mode\": \"{mode:?}\",");
    let _ = writeln!(
        json,
        "  \"load\": {{\"prompt_len\": {prompt_len}, \"max_new\": {max_new}, \
         \"reqs_per_client\": {reqs_per_client}}},"
    );
    json.push_str("  \"results\": [\n");
    for (i, r) in records.iter().enumerate() {
        let _ = write!(json, "    {}", r.to_json());
        json.push_str(if i + 1 < records.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    let dir = out_dir();
    std::fs::create_dir_all(&dir)?;
    let json_path = dir.join("serve_load.json");
    std::fs::write(&json_path, json)?;
    println!("json: {}", json_path.display());
    Ok(())
}
