//! Parallel scaling — wall time of the serving hot paths vs thread count.
//!
//! The backend (`exec::pool`) guarantees bitwise identical results at any
//! thread count, so this bench measures the only thing threads are
//! allowed to change: wall time.  Three cases per thread count:
//!
//!   * `prefill`        — one full-context forward (32k tokens in full
//!                        mode) through the padded, head-parallel,
//!                        tile-parallel prefill path;
//!   * `batched_decode` — the continuous-batching scheduler draining
//!                        concurrent sessions (per-session parallel
//!                        stepping);
//!   * `serve_load`     — the multi-worker serving pool completing a
//!                        closed batch of requests end to end.
//!
//! Results print as a table and persist to
//! `bench_out/parallel_scaling.json` with per-case speedups vs 1 thread.
//! In every mode the bench self-checks that max threads is not slower
//! than 1 thread on the prefill case (with generous noise slack) and
//! fails loudly otherwise — the CI smoke gate.

use std::fmt::Write as _;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use polysketchformer::attn::Mechanism;
use polysketchformer::bench::{banner, out_dir, Mode, Table};
use polysketchformer::exec::pool;
use polysketchformer::infer::{
    GenRequest, LmConfig, NativeLm, SamplePolicy, Scheduler, SchedulerConfig,
};
use polysketchformer::metrics::{Record, ServeCounters};
use polysketchformer::serve::{PromptCache, ServeJob, TokenEvent, WorkerConfig, WorkerPool};

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("parallel_scaling", "threads x {prefill, batched decode, serve load}", mode);

    let max_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut threads: Vec<usize> = [1usize, 2, 4, 8, max_threads]
        .into_iter()
        .filter(|&t| t <= max_threads)
        .collect();
    threads.sort_unstable();
    threads.dedup();

    // The acceptance-criterion configuration: 32k-context polysketch.
    let prefill_ctx = mode.pick(2048, 8192, 32_768);
    let decode_sessions = mode.pick(4, 8, 8);
    let decode_tokens = mode.pick(8, 24, 48);
    let serve_requests = mode.pick(4, 12, 24);
    let serve_tokens = mode.pick(6, 12, 24);

    let mech = Mechanism::parse("psk4_r16_b64_local").expect("bench mechanism");
    let cfg = LmConfig { d_model: 64, layers: 2, heads: 4, ..LmConfig::default() };
    let model = Arc::new(NativeLm::new(cfg.clone(), mech.clone()));
    let prefill_prompt: Vec<u32> =
        (0..prefill_ctx).map(|i| (i as u32).wrapping_mul(2654435761) % 257).collect();

    let cases = ["prefill", "batched_decode", "serve_load"];
    let mut table = Table::new(
        &format!("wall seconds vs threads ({}, d=64 L=2 H=4)", mech.label()),
        "case",
        threads.iter().map(|t| format!("t={t}")).collect(),
    );
    let mut records: Vec<Record> = Vec::new();
    // secs[case][thread_idx]
    let mut secs: Vec<Vec<f64>> = vec![Vec::new(); cases.len()];

    for &t in &threads {
        pool::set_threads(t);

        // -- prefill ----------------------------------------------------
        // Min over a few repetitions: the CI gate compares thread counts
        // on this number, and a single sample on a shared runner flakes.
        let reps = mode.pick(3, 2, 1);
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let t0 = Instant::now();
            let logits = model.forward(&prefill_prompt);
            best = best.min(t0.elapsed().as_secs_f64());
            assert!(logits.data().iter().all(|x| x.is_finite()));
        }
        secs[0].push(best);

        // -- batched decode --------------------------------------------
        let sched_cfg = SchedulerConfig { max_concurrent: 4, tick_tokens: 16, ..Default::default() };
        let mut sched = Scheduler::new(&model, sched_cfg);
        for i in 0..decode_sessions {
            sched.submit(GenRequest {
                prompt: prefill_prompt[..256.min(prefill_prompt.len())].to_vec(),
                max_new_tokens: decode_tokens,
                policy: SamplePolicy::Greedy,
                seed: i as u64,
            });
        }
        let t0 = Instant::now();
        let summary = sched.run()?;
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(summary.reports.len(), decode_sessions);
        secs[1].push(dt);

        // -- serve load -------------------------------------------------
        let cache = Arc::new(PromptCache::new(64 << 20));
        let counters = Arc::new(ServeCounters::new());
        let wp = WorkerPool::new(
            Arc::clone(&model),
            cache,
            Arc::clone(&counters),
            WorkerConfig { workers: 2, slice_tokens: 4, max_resident: 8 },
        );
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..serve_requests)
            .map(|i| {
                let (tx, rx) = channel();
                wp.try_submit(
                    ServeJob {
                        id: i as u64,
                        req: GenRequest {
                            // Vary prompts so serve load measures prefill
                            // throughput, not pure cache hits.
                            prompt: prefill_prompt
                                [(i * 16) % 512..(i * 16) % 512 + 128]
                                .to_vec(),
                            max_new_tokens: serve_tokens,
                            policy: SamplePolicy::Greedy,
                            seed: i as u64,
                        },
                        events: tx,
                        queued: Instant::now(),
                        trace: 0,
                    },
                    1024,
                )
                .ok()
                .expect("admission under cap");
                rx
            })
            .collect();
        for rx in rxs {
            let done = rx.iter().any(|ev| matches!(ev, TokenEvent::Done(_)));
            assert!(done, "request must complete");
        }
        wp.drain();
        let dt = t0.elapsed().as_secs_f64();
        secs[2].push(dt);

        for (ci, case) in cases.iter().enumerate() {
            records.push(
                Record::new()
                    .str("case", case)
                    .str("mech", mech.label())
                    .i64("threads", t as i64)
                    .i64("prefill_ctx", prefill_ctx as i64)
                    .f64("secs", secs[ci][secs[ci].len() - 1]),
            );
        }
    }
    pool::set_threads(pool::default_threads());

    for (ci, case) in cases.iter().enumerate() {
        table.row(
            case,
            secs[ci]
                .iter()
                .map(|&s| format!("{s:.3}s ({:.2}x)", secs[ci][0] / s.max(1e-12)))
                .collect(),
        );
    }
    print!("{}", table.render());
    println!("csv: {}", table.save_csv("parallel_scaling")?.display());

    // JSON artifact (hand-rolled like the other benches; no serde here).
    let mut json = String::from("{\n  \"bench\": \"parallel_scaling\",\n");
    let _ = writeln!(json, "  \"mode\": \"{mode:?}\",");
    let _ = writeln!(json, "  \"mech\": \"{}\",", mech.label());
    let _ = writeln!(
        json,
        "  \"model\": {{\"d_model\": {}, \"layers\": {}, \"heads\": {}}},",
        cfg.d_model, cfg.layers, cfg.heads
    );
    let _ = writeln!(json, "  \"max_threads\": {max_threads},");
    json.push_str("  \"results\": [\n");
    for (i, r) in records.iter().enumerate() {
        let _ = write!(json, "    {}", r.to_json());
        json.push_str(if i + 1 < records.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    let dir = out_dir();
    std::fs::create_dir_all(&dir)?;
    let json_path = dir.join("parallel_scaling.json");
    std::fs::write(&json_path, json)?;
    println!("json: {}", json_path.display());

    // Self-check (the CI gate): threads=max must not be slower than
    // threads=1 on prefill.  0.8 slack absorbs timer noise; on a 1-core
    // runner the sweep is a single point and the check is vacuous.
    let t1 = secs[0][0];
    let tmax = *secs[0].last().unwrap();
    let speedup = t1 / tmax.max(1e-12);
    if threads.len() > 1 && speedup < 0.8 {
        anyhow::bail!(
            "PARALLEL_SCALING_CHECK fail: prefill at {} threads is {speedup:.2}x vs 1 thread",
            threads.last().unwrap()
        );
    }
    println!(
        "PARALLEL_SCALING_CHECK pass: prefill speedup {speedup:.2}x at {} threads (ctx {prefill_ctx})",
        threads.last().unwrap()
    );
    Ok(())
}
