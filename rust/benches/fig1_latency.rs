//! Figure 1 — train-step latency per token (µs/token) vs context length.
//!
//! The paper's headline plot: GPT-2-small-style models trained with 1M-token
//! batches; softmax/FlashAttention latency grows with context while
//! Polysketch stays flat, reaching 2x at 32k context.
//!
//! This bench regenerates the *shape* on this testbed through two paths:
//!
//!  1. native-kernel sweep — one attention layer fwd + bwd-equivalent cost
//!     model (fwd timed; training cost is a constant multiple) across
//!     ctx 512 .. 32k at a fixed token budget per step, all mechanisms;
//!  2. AOT train-step sweep — the actual PJRT train executables at the
//!     artifact context lengths (64 / 128 / 256), measuring real fused
//!     fwd+bwd+Adam steps per second at a fixed 2048-token budget.
//!
//! Expected shape (paper): quadratic mechanisms' µs/token doubles with each
//! ctx doubling and OOMs/slows past 8k; kernel-based mechanisms stay flat;
//! crossover vs FlashAttention lands between 1k and 8k.

use polysketchformer::attn::Mechanism;
use polysketchformer::bench::{banner, time_fn, Mode, Table};
use polysketchformer::data::random_tokens;
use polysketchformer::runtime::{self, LoadOpts};
use polysketchformer::tensor::Tensor;
use polysketchformer::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("fig1_latency", "Figure 1 (+ Figure 4 latency curves)", mode);
    native_sweep(mode)?;
    aot_train_sweep(mode)?;
    Ok(())
}

/// Part 1: native kernels, µs/token vs ctx (Figure 1's axes).
fn native_sweep(mode: Mode) -> anyhow::Result<()> {
    let max_ctx = mode.pick(2048, 16384, 32768);
    let iters = mode.pick(1, 2, 3);
    let head_dim = 32;

    let mechanisms = [
        Mechanism::Softmax,
        Mechanism::Flash { block: 256 },
        Mechanism::Flash { block: 512 },
        Mechanism::Poly { p: 4 },
        Mechanism::Polysketch { r: 16, p: 4, block: 256, local: true },
        Mechanism::Polysketch { r: 32, p: 4, block: 256, local: true },
        Mechanism::Performer { m: 64, block: 256 },
    ];

    let mut ctxs = Vec::new();
    let mut c = 512usize;
    while c <= max_ctx {
        ctxs.push(c);
        c *= 2;
    }

    let mut table = Table::new(
        "Figure 1 analog — native attention µs/token (fwd), head_dim=32",
        "mechanism",
        ctxs.iter().map(|c| c.to_string()).collect(),
    );

    let mut rng = Pcg::seeded(0);
    for mech in &mechanisms {
        let attn = mech.build_kernel(head_dim, &mut rng);
        let mut cells = Vec::new();
        for &n in &ctxs {
            // Paper: vanilla softmax OOMs beyond 8k; naive softmax here is
            // time-bound instead of memory-bound — mark it the same way.
            let quadratic_cap = match mech {
                Mechanism::Softmax | Mechanism::Poly { .. } => 8192,
                Mechanism::Flash { .. } => 16384,
                _ => usize::MAX,
            };
            if n > quadratic_cap {
                cells.push("OOM".into());
                continue;
            }
            let q = Tensor::gaussian(&mut rng, &[n, head_dim]);
            let k = Tensor::gaussian(&mut rng, &[n, head_dim]);
            let v = Tensor::gaussian(&mut rng, &[n, head_dim]);
            let t = time_fn(1, iters, || {
                std::hint::black_box(attn.forward(&q, &k, &v));
            });
            cells.push(format!("{:.2}", t.mean_us() / n as f64));
        }
        table.row(&mech.label(), cells);
    }
    print!("{}", table.render());
    let path = table.save_csv("fig1_native_us_per_token")?;
    println!("csv: {}\n", path.display());
    Ok(())
}

/// Part 2: real AOT train steps/sec at a fixed 2048-token budget
/// (batch x ctx constant across artifact context lengths).
fn aot_train_sweep(mode: Mode) -> anyhow::Result<()> {
    let steps = mode.pick(2, 3, 8);
    // (mechanism label, artifact prefix); the full artifact family is
    // exercised by table4/fig2 — keep this sweep to the headline four.
    let mechs = [
        ("softmax", "softmax"),
        ("poly4", "poly4"),
        ("psk_learned_local_r16", "psk4_r16_learned_local"),
        ("performer64", "performer64"),
    ];
    let ctxs: &[usize] = if mode == Mode::Smoke { &[64] } else { &[64, 128, 256] };

    let mut table = Table::new(
        "Figure 1 analog — AOT train step µs/token (fused fwd+bwd+Adam, 2048 tok/step)",
        "mechanism",
        ctxs.iter().map(|c| c.to_string()).collect(),
    );

    for (label, prefix) in mechs {
        let mut cells = Vec::new();
        for &ctx in ctxs {
            let name = format!("{prefix}_v512_d128_l4_h4x32_c{ctx}");
            let mut model = match runtime::load_model(&name, LoadOpts::train_only()) {
                Ok(m) => m,
                Err(_) => {
                    cells.push("-".into());
                    continue;
                }
            };
            let tokens_per_step = model.batch() * (model.ctx() + 1);
            let batch = random_tokens(tokens_per_step, model.vocab(), 0)
                .into_iter()
                .map(|t| t as i32)
                .collect::<Vec<_>>();
            let t = time_fn(1, steps, || {
                model.train_step(&batch).expect("train step");
            });
            cells.push(format!("{:.1}", t.mean_us() / tokens_per_step as f64));
        }
        table.row(label, cells);
    }
    print!("{}", table.render());
    let path = table.save_csv("fig1_aot_train_us_per_token")?;
    println!("csv: {}", path.display());
    Ok(())
}
