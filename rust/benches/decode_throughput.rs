//! Decode throughput — per-token generation cost vs context length.
//!
//! The serving-side corollary of the paper's linearity result: the
//! recurrent view of polysketch/performer attention makes each generated
//! token an O(1) state update, while the softmax family rescans an O(n)
//! KV cache.  This bench prefills a native LM at each context length,
//! then times token-by-token decoding through the per-head `KernelState`s:
//!
//!   expected shape — µs/token flat (within noise) across the 512 -> 8k
//!   sweep for psk*/performer*, growing roughly linearly for
//!   softmax/flash/poly; decode-state memory constant vs linear likewise.
//!
//! Results print as a paper-style table, persist as CSV, and additionally
//! as a JSON artifact (`bench_out/decode_throughput.json`) so future PRs
//! can track the serving-path trajectory alongside the training benches.

use std::fmt::Write as _;

use polysketchformer::attn::Mechanism;
use polysketchformer::bench::{banner, out_dir, Mode, Table};
use polysketchformer::infer::{GenRequest, LmConfig, NativeLm, SamplePolicy};
use polysketchformer::infer::session::DecodeSession;
use polysketchformer::metrics::Record;

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("decode_throughput", "serving-path corollary of Figure 1 (µs/token decode)", mode);

    // Mechanism labels go through Mechanism::parse — the single spelling
    // shared with the `generate` subcommand.
    let mech_labels = [
        "softmax",
        "flash_b256",
        "poly4",
        "psk4_r16_b64",
        "psk4_r16_b64_local",
        "performer64_b64",
    ];
    let max_ctx = mode.pick(1024, 8192, 8192);
    let decode_steps = mode.pick(4, 16, 32);
    // Quadratic-prefill guard: naive softmax/poly prefill at 8k is minutes
    // of wall time in quick mode; cap like fig1 does and mark the cell.
    let prefill_cap = mode.pick(usize::MAX, 4096, usize::MAX);

    let mut ctxs = Vec::new();
    let mut c = 512usize;
    while c <= max_ctx {
        ctxs.push(c);
        c *= 2;
    }

    let cfg = LmConfig { d_model: 64, layers: 2, heads: 2, ..LmConfig::default() };
    let mut table = Table::new(
        "decode µs/token vs context (native LM, d=64 L=2 H=2)",
        "mechanism",
        ctxs.iter().map(|c| c.to_string()).collect(),
    );
    let mut mem_table = Table::new(
        "decode-state memory (f32 KWords) vs context",
        "mechanism",
        ctxs.iter().map(|c| c.to_string()).collect(),
    );
    let mut records: Vec<Record> = Vec::new();

    for label in mech_labels {
        let mech = Mechanism::parse(label).expect("bench mechanism labels must parse");
        let model = NativeLm::new(cfg.clone(), mech.clone());
        let mut cells = Vec::new();
        let mut mem_cells = Vec::new();
        for &ctx in &ctxs {
            if !mech.is_linear() && ctx > prefill_cap {
                cells.push("-".into());
                mem_cells.push("-".into());
                continue;
            }
            // Deterministic prompt of `ctx` tokens, then timed decoding.
            let prompt: Vec<u32> =
                (0..ctx).map(|i| (i as u32).wrapping_mul(2654435761) % 257).collect();
            let req = GenRequest {
                prompt,
                max_new_tokens: decode_steps,
                policy: SamplePolicy::Greedy,
                seed: 0,
            };
            let mut session = DecodeSession::new(&model, 0, req);
            session.run_to_completion(&model);
            let us_per_token = session.decode_secs * 1e6 / decode_steps as f64;
            let state_floats = session.state_memory_floats();
            cells.push(format!("{us_per_token:.1}"));
            mem_cells.push(format!("{:.1}", state_floats as f64 / 1e3));
            records.push(
                Record::new()
                    .str("mech", mech.label())
                    .bool("linear", mech.is_linear())
                    .i64("ctx", ctx as i64)
                    .i64("decode_steps", decode_steps as i64)
                    .f64("prefill_ms", session.prefill_secs * 1e3)
                    .f64("us_per_token", us_per_token)
                    .f64("decode_tokens_per_sec", 1e6 / us_per_token.max(1e-9))
                    .i64("state_memory_floats", state_floats as i64),
            );
        }
        table.row(label, cells);
        mem_table.row(label, mem_cells);
    }

    print!("{}", table.render());
    println!("csv: {}\n", table.save_csv("decode_throughput_us_per_token")?.display());
    print!("{}", mem_table.render());
    println!("csv: {}", mem_table.save_csv("decode_throughput_state_memory")?.display());

    // JSON artifact: one object with every (mech, ctx) record, assembled
    // from the same hand-rolled encoder metrics uses (no serde here).
    let mut json = String::from("{\n  \"bench\": \"decode_throughput\",\n");
    let _ = writeln!(json, "  \"mode\": \"{mode:?}\",");
    let _ = writeln!(json, "  \"model\": {{\"d_model\": {}, \"layers\": {}, \"heads\": {}}},",
                     cfg.d_model, cfg.layers, cfg.heads);
    json.push_str("  \"results\": [\n");
    for (i, r) in records.iter().enumerate() {
        let _ = write!(json, "    {}", r.to_json());
        json.push_str(if i + 1 < records.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    let dir = out_dir();
    std::fs::create_dir_all(&dir)?;
    let json_path = dir.join("decode_throughput.json");
    std::fs::write(&json_path, json)?;
    println!("json: {}", json_path.display());
    Ok(())
}
