//! Ablation — sketch size r (Theorem 1.1 / Section 2.2 empirically).
//!
//! Sweeps r over {8, 16, 32, 64, 128} and reports, for the non-negative
//! polysketch feature map φ'(x) = ((x^{⊗p/2})ᵀS)^{⊗2}:
//!
//!   * relative AMM error ‖φ'(Q)φ'(K)ᵀ − (QKᵀ)^p‖_F / (‖Q^⊗p‖_F ‖K^⊗p‖_F)
//!     — Theorem 1.1 predicts ~ sqrt(p/r) decay;
//!   * min attention weight (must be >= 0: the non-negativity guarantee);
//!   * attention latency vs r (the quality/speed dial, Tables 2-4).
//!
//! Expected shape: error halves roughly per 4x r; min weight never negative;
//! latency grows ~r (the r² feature dim never materializes per block).

use polysketchformer::attn::sketch::PolySketch;
use polysketchformer::attn::Mechanism;
use polysketchformer::bench::{banner, time_fn, Mode, Table};
use polysketchformer::tensor::{layernorm_rows, Tensor};
use polysketchformer::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    let mode = Mode::from_env();
    banner("ablation_sketch", "sketch-size ablation (Thm 1.1, Tables 2-4 r dial)", mode);
    let n = mode.pick(128, 512, 1024);
    let latency_n = mode.pick(1024, 4096, 16384);
    let trials = mode.pick(1, 3, 5);
    let h = 32;
    let p = 4u32;
    let rs = [8usize, 16, 32, 64, 128];

    let mut table = Table::new(
        &format!("sketch-size ablation — degree {p}, head_dim {h}, n {n}"),
        "r",
        vec![
            "rel AMM err".into(),
            "min weight".into(),
            format!("attn ms (n={latency_n})"),
        ],
    );

    let mut rng = Pcg::seeded(0);
    let q = layernorm_rows(&Tensor::gaussian(&mut rng, &[n, h]));
    let k = layernorm_rows(&Tensor::gaussian(&mut rng, &[n, h]));

    // Exact (QK^T)^p and the Frobenius normalizer ||Q^{(x)p}|| ||K^{(x)p}||
    // (= product of row-norm^p sums, no h^p materialization needed).
    let qk = q.matmul_t(&k);
    let mut exact = qk.clone();
    for x in exact.data_mut() {
        *x = x.powi(p as i32);
    }
    let normalizer = frob_pow(&q, p) * frob_pow(&k, p);

    for &r in &rs {
        let mut err_sum = 0.0f64;
        let mut min_w = f64::INFINITY;
        for t in 0..trials {
            let sk = PolySketch::sample(&mut Pcg::seeded(100 + t as u64), h, r, p as usize);
            let phi_q = sk.nonnegative(&q);
            let phi_k = sk.nonnegative(&k);
            let approx = phi_q.matmul_t(&phi_k);
            let mut err = 0.0f64;
            for (a, e) in approx.data().iter().zip(exact.data()) {
                err += ((a - e) as f64).powi(2);
                min_w = min_w.min(*a as f64);
            }
            err_sum += err.sqrt() / normalizer;
        }
        let rel_err = err_sum / trials as f64;

        let mech = Mechanism::Polysketch { r, p, block: 256, local: true };
        let attn = mech.build_kernel(h, &mut rng);
        let ql = Tensor::gaussian(&mut rng, &[latency_n, h]);
        let kl = Tensor::gaussian(&mut rng, &[latency_n, h]);
        let vl = Tensor::gaussian(&mut rng, &[latency_n, h]);
        let timing = time_fn(1, 2, || {
            std::hint::black_box(attn.forward(&ql, &kl, &vl));
        });

        table.row(
            &r.to_string(),
            vec![
                format!("{rel_err:.4}"),
                format!("{min_w:.2e}"),
                format!("{:.1}", timing.mean_ms()),
            ],
        );
        println!("r={r} done");
    }
    print!("{}", table.render());
    println!("csv: {}", table.save_csv("ablation_sketch")?.display());
    Ok(())
}

/// ||A^{(x)p}||_F = sqrt(sum_i ||a_i||^{2p}).
fn frob_pow(a: &Tensor, p: u32) -> f64 {
    let mut total = 0.0f64;
    for i in 0..a.rows() {
        let norm2: f64 = a.row(i).iter().map(|&x| (x as f64) * (x as f64)).sum();
        total += norm2.powi(p as i32);
    }
    total.sqrt()
}
