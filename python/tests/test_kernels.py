"""Kernel correctness: scan + Pallas implementations vs the naive oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.common import layernorm, self_tensor
from compile.kernels import ref, sketch
from compile.kernels.linear_attn import (block_linear_attention,
                                         block_polysketch_attention)
from compile.kernels.pallas import (linear_attention_pallas,
                                    poly_attention_pallas,
                                    polysketch_attention_pallas,
                                    softmax_attention_pallas)

jax.config.update("jax_enable_x64", False)


def rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------- oracles

class TestOracleInvariants:
    def test_softmax_rows_sum_to_one(self):
        kq, kk, kv = keys(0, 3)
        q, k, v = rand(kq, 16, 8), rand(kk, 16, 8), rand(kv, 16, 8)
        out = ref.softmax_attention(q, k, jnp.eye(16))
        np.testing.assert_allclose(np.sum(out, -1), 1.0, rtol=1e-5)

    def test_softmax_causality(self):
        kq, kk, kv, kp = keys(1, 4)
        q, k, v = rand(kq, 16, 8), rand(kk, 16, 8), rand(kv, 16, 8)
        out1 = ref.softmax_attention(q, k, v)
        # Perturbing the future must not change earlier outputs.
        v2 = v.at[10:].set(rand(kp, 6, 8))
        out2 = ref.softmax_attention(q, k, v2)
        np.testing.assert_allclose(out1[:10], out2[:10], rtol=1e-6)

    def test_poly_attention_weights_nonnegative_even_p(self):
        kq, kk = keys(2, 2)
        q, k = rand(kq, 12, 8), rand(kk, 12, 8)
        out = ref.poly_attention(q, k, jnp.eye(12), p=4)
        assert np.all(np.asarray(out) >= -1e-7)

    def test_poly_attention_row_sums_below_one(self):
        # 1+ in the denominator => rows sum to sum/(1+sum) < 1.
        kq, kk = keys(3, 2)
        q, k = rand(kq, 12, 8), rand(kk, 12, 8)
        out = ref.poly_attention(q, k, jnp.eye(12), p=4)
        rows = np.sum(np.asarray(out), -1)
        assert np.all(rows < 1.0) and np.all(rows >= 0.0)

    def test_poly_attention_argmax_limit(self):
        # As p grows, weight concentrates on the max inner product (Sec 2.1).
        kq, kk = keys(4, 2)
        q, k = rand(kq, 8, 16), rand(kk, 8, 16)
        w8 = ref.poly_attention(q, k, jnp.eye(8), p=8, causal=False)
        qn, kn = layernorm(q), layernorm(k)
        s = np.asarray(qn @ kn.T)
        am = np.argmax(np.abs(s), axis=-1)
        got = np.argmax(np.asarray(w8), axis=-1)
        assert np.mean(am == got) >= 0.8

    def test_lt_mult_matches_definition(self):
        ka, kb, kc = keys(5, 3)
        a, b, c = rand(ka, 10, 4), rand(kb, 10, 4), rand(kc, 10, 3)
        got = ref.lt_mult(a, b, c)
        want = np.tril(np.asarray(a) @ np.asarray(b).T) @ np.asarray(c)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


# ---------------------------------------------------------------- sketches

class TestSketches:
    @pytest.mark.parametrize("p", [2, 4, 8])
    def test_projection_count_matches_paper(self, p):
        # phi' of degree p consumes p-2 projections (Section 2.3).
        assert sketch.num_projections(p // 2) == p - 2

    @pytest.mark.parametrize("p,r,bound", [(2, 16, 0.6), (4, 16, 0.6),
                                           (4, 32, 0.45), (8, 16, 1.6)])
    def test_pswn_approximates_poly_kernel(self, p, r, bound):
        kd, kg = keys(6, 2)
        x = rand(kd, 64, 8)
        x = x / jnp.linalg.norm(x, axis=-1, keepdims=True)
        gs = sketch.sample_projections(kg, 8, r, p)
        sk = sketch.polysketch_with_negativity(x, gs, r, p)
        approx = np.asarray(sk @ sk.T)
        exact = np.asarray(x @ x.T) ** p
        err = np.sqrt(np.mean((approx - exact) ** 2))
        # AMM-style bound for unit rows; variance grows with degree p
        # (Theorem 2.2's r = Theta(p / eps^2)), hence per-case bounds.
        assert err < bound

    @pytest.mark.parametrize("p,r", [(2, 8), (4, 8), (4, 16), (8, 8)])
    def test_nonnegative_sketch_is_nonnegative(self, p, r):
        kq, kk, kg = keys(7, 3)
        q, k = rand(kq, 32, 8), rand(kk, 32, 8)
        gs = sketch.sample_projections(kg, 8, r, p)
        pq = sketch.polysketch_nonnegative(q, gs, r, p)
        pk = sketch.polysketch_nonnegative(k, gs, r, p)
        w = np.asarray(pq @ pk.T)
        assert np.all(w >= -1e-6), "Theorem 1.1 property 1 violated"

    def test_self_tensor_inner_product_is_square(self):
        ka, kb = keys(8, 2)
        a, b = rand(ka, 5, 6), rand(kb, 5, 6)
        sa, sb = self_tensor(a), self_tensor(b)
        got = np.asarray(jnp.einsum("if,jf->ij", sa, sb))
        want = np.asarray(a @ b.T) ** 2
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_half_sketch_consistent_with_nonnegative(self):
        kd, kg = keys(9, 2)
        x = rand(kd, 16, 8)
        gs = sketch.sample_projections(kg, 8, 8, 4)
        half = sketch.half_sketch(x, gs, 8, 4)
        full = sketch.polysketch_nonnegative(x, gs, 8, 4)
        np.testing.assert_allclose(np.asarray(self_tensor(half)),
                                   np.asarray(full), rtol=1e-5)

    def test_sketch_error_shrinks_with_r(self):
        kd, kg = keys(10, 2)
        x = rand(kd, 64, 8)
        x = x / jnp.linalg.norm(x, axis=-1, keepdims=True)
        errs = []
        for r in (4, 16, 64):
            gs = sketch.sample_projections(kg, 8, r, 4)
            sk = sketch.polysketch_with_negativity(x, gs, r, 4)
            approx = np.asarray(sk @ sk.T)
            exact = np.asarray(x @ x.T) ** 4
            errs.append(np.sqrt(np.mean((approx - exact) ** 2)))
        assert errs[2] < errs[0], f"error did not shrink: {errs}"

    def test_degree_must_be_power_of_two(self):
        with pytest.raises(ValueError):
            sketch.num_projections(3)


# ----------------------------------------------------- block scan vs oracle

class TestBlockScan:
    @pytest.mark.parametrize("n,f,h,block", [(32, 8, 4, 8), (64, 16, 8, 16),
                                             (64, 16, 8, 64), (48, 4, 4, 16)])
    def test_block_linear_matches_oracle(self, n, f, h, block):
        kq, kk, kv = keys(11, 3)
        pq = jnp.abs(rand(kq, n, f))
        pk = jnp.abs(rand(kk, n, f))
        v = rand(kv, n, h)
        got = block_linear_attention(pq, pk, v, block)
        want = ref.linear_attention(pq, pk, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("block", [8, 16, 32])
    def test_block_polysketch_matches_oracle(self, block):
        kq, kk, kv, kg = keys(12, 4)
        n, h, rs = 32, 8, 4
        q, k, v = rand(kq, n, h), rand(kk, n, h), rand(kv, n, h)
        gs = sketch.sample_projections(kg, h, rs, 4)
        l = sketch.half_sketch(layernorm(q), gs, rs, 4)
        r = sketch.half_sketch(layernorm(k), gs, rs, 4)
        got = block_polysketch_attention(l, r, v, block)
        want = ref.polysketch_attention(l, r, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)

    def test_block_polysketch_local_exact_matches_oracle(self):
        kq, kk, kv, kg = keys(13, 4)
        n, h, rs, block, p = 32, 8, 4, 8, 4
        q, k, v = rand(kq, n, h), rand(kk, n, h), rand(kv, n, h)
        gs = sketch.sample_projections(kg, h, rs, p)
        l = sketch.half_sketch(layernorm(q), gs, rs, p)
        r = sketch.half_sketch(layernorm(k), gs, rs, p)
        got = block_polysketch_attention(l, r, v, block, q=q, k=k, p=p,
                                         local_exact=True)
        want = ref.polysketch_attention(l, r, v, q=q, k=k, p=p, block=block)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)

    def test_block_size_equals_n_is_exact_quadratic(self):
        # One block => pure lt(S)C path, no prefix state involved.
        kq, kk, kv = keys(14, 3)
        n, f, h = 16, 8, 4
        pq, pk, v = jnp.abs(rand(kq, n, f)), jnp.abs(rand(kk, n, f)), rand(kv, n, h)
        got = block_linear_attention(pq, pk, v, n)
        want = ref.linear_attention(pq, pk, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    def test_indivisible_block_raises(self):
        kq, kk, kv = keys(15, 3)
        with pytest.raises(ValueError):
            block_linear_attention(rand(kq, 10, 4), rand(kk, 10, 4),
                                   rand(kv, 10, 4), 3)


# ------------------------------------------------------- pallas vs oracle

class TestPallasKernels:
    @pytest.mark.parametrize("n,h,block", [(32, 8, 8), (64, 16, 16)])
    def test_softmax_pallas_matches_oracle(self, n, h, block):
        kq, kk, kv = keys(16, 3)
        q, k, v = rand(kq, n, h), rand(kk, n, h), rand(kv, n, h)
        got = softmax_attention_pallas(q, k, v, block_q=block, block_k=block)
        want = ref.softmax_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("p", [2, 4, 8])
    def test_poly_pallas_matches_oracle(self, p):
        kq, kk, kv = keys(17, 3)
        n, h = 32, 8
        q, k, v = rand(kq, n, h), rand(kk, n, h), rand(kv, n, h)
        got = poly_attention_pallas(q, k, v, p=p, block_q=8, block_k=8)
        want = ref.poly_attention(q, k, v, p=p)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-5)

    @pytest.mark.parametrize("block", [8, 16])
    def test_linear_pallas_matches_oracle(self, block):
        kq, kk, kv = keys(18, 3)
        n, f, h = 32, 8, 8
        pq = jnp.abs(rand(kq, n, f))
        pk = jnp.abs(rand(kk, n, f))
        v = rand(kv, n, h)
        got = linear_attention_pallas(pq, pk, v, block=block)
        want = ref.linear_attention(pq, pk, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("block", [8, 16])
    def test_polysketch_pallas_matches_oracle(self, block):
        kq, kk, kv, kg = keys(19, 4)
        n, h, rs = 32, 8, 4
        q, k, v = rand(kq, n, h), rand(kk, n, h), rand(kv, n, h)
        gs = sketch.sample_projections(kg, h, rs, 4)
        l = sketch.half_sketch(layernorm(q), gs, rs, 4)
        r = sketch.half_sketch(layernorm(k), gs, rs, 4)
        got = polysketch_attention_pallas(l, r, v, block=block)
        want = ref.polysketch_attention(l, r, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)

    def test_polysketch_pallas_local_exact(self):
        kq, kk, kv, kg = keys(20, 4)
        n, h, rs, block, p = 32, 8, 4, 8, 4
        q, k, v = rand(kq, n, h), rand(kk, n, h), rand(kv, n, h)
        gs = sketch.sample_projections(kg, h, rs, p)
        l = sketch.half_sketch(layernorm(q), gs, rs, p)
        r = sketch.half_sketch(layernorm(k), gs, rs, p)
        got = polysketch_attention_pallas(l, r, v, block=block, q=q, k=k, p=p,
                                          local_exact=True)
        want = ref.polysketch_attention(l, r, v, q=q, k=k, p=p, block=block)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)

    def test_pallas_agrees_with_scan_impl(self):
        # Pallas forward and the differentiable scan must agree bit-closely.
        kq, kk, kv, kg = keys(21, 4)
        n, h, rs, block = 64, 8, 4, 16
        q, k, v = rand(kq, n, h), rand(kk, n, h), rand(kv, n, h)
        gs = sketch.sample_projections(kg, h, rs, 4)
        l = sketch.half_sketch(layernorm(q), gs, rs, 4)
        r = sketch.half_sketch(layernorm(k), gs, rs, 4)
        a = polysketch_attention_pallas(l, r, v, block=block)
        b = block_polysketch_attention(l, r, v, block)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


# -------------------------------------------------------------- performer

class TestPerformer:
    def test_features_positive(self):
        kx, kw = keys(22, 2)
        x = rand(kx, 16, 8)
        w = rand(kw, 8, 32)
        f = np.asarray(ref.performer_features(x, w))
        assert np.all(f > 0)

    def test_performer_runs_through_block_lt(self):
        kq, kk, kv, kw = keys(23, 4)
        n, h, m = 32, 8, 16
        q, k, v = rand(kq, n, h), rand(kk, n, h), rand(kv, n, h)
        w = rand(kw, h, m)
        want = ref.performer_attention(q, k, v, w)
        pq = ref.performer_features(q, w)
        pk = ref.performer_features(k, w)
        got = block_linear_attention(pq, pk, v, 8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)
