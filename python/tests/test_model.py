"""L2 model tests: shapes, mechanisms, training signal, flat-theta packing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile import train as T
from compile.sketch_layers import (learnable_half_sketch,
                                   learnable_sketch_init, param_count,
                                   sketch_net_apply, sketch_net_init)

TINY = dict(vocab=64, d_model=32, n_layers=2, n_heads=2, head_dim=16,
            ctx=32, block=16)


def _cfg(**kw):
    return M.ModelConfig(**{**TINY, **kw})


MECHS = [
    _cfg(attn="softmax"),
    _cfg(attn="poly", degree=4),
    _cfg(attn="polysketch", degree=4, sketch_size=8, sketch_mode="learned",
         local_exact=True),
    _cfg(attn="polysketch", degree=4, sketch_size=8, sketch_mode="learned",
         local_exact=False),
    _cfg(attn="polysketch", degree=4, sketch_size=8, sketch_mode="random",
         local_exact=True),
    _cfg(attn="performer", performer_features=16),
]


def _tokens(cfg, batch=2, extra=0, seed=0):
    key = jax.random.PRNGKey(seed)
    return jax.random.randint(key, (batch, cfg.ctx + extra), 0, cfg.vocab,
                              dtype=jnp.int32)


class TestForward:
    @pytest.mark.parametrize("cfg", MECHS, ids=lambda c: c.name())
    def test_forward_shape_and_finite(self, cfg):
        params, statics = M.init(jax.random.PRNGKey(0), cfg)
        toks = _tokens(cfg)
        logits = M.forward(params, statics, cfg, toks)
        assert logits.shape == (2, cfg.ctx, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits)))

    @pytest.mark.parametrize("cfg", MECHS[:3], ids=lambda c: c.name())
    def test_causality(self, cfg):
        # Changing token t must not affect logits before t.
        params, statics = M.init(jax.random.PRNGKey(0), cfg)
        toks = _tokens(cfg)
        cut = cfg.ctx // 2
        toks2 = toks.at[:, cut:].set((toks[:, cut:] + 1) % cfg.vocab)
        l1 = M.forward(params, statics, cfg, toks)
        l2 = M.forward(params, statics, cfg, toks2)
        np.testing.assert_allclose(np.asarray(l1[:, :cut]),
                                   np.asarray(l2[:, :cut]), rtol=1e-4,
                                   atol=1e-5)

    def test_initial_loss_near_uniform(self):
        cfg = MECHS[2]
        params, statics = M.init(jax.random.PRNGKey(0), cfg)
        loss = M.loss_fn(params, statics, cfg, _tokens(cfg, extra=1))
        assert abs(float(loss) - np.log(cfg.vocab)) < 0.5

    def test_pallas_and_scan_model_agree(self):
        cfg = _cfg(attn="polysketch", degree=4, sketch_size=8,
                   sketch_mode="random", local_exact=True)
        cfg_p = _cfg(attn="polysketch", degree=4, sketch_size=8,
                     sketch_mode="random", local_exact=True, use_pallas=True)
        params, statics = M.init(jax.random.PRNGKey(0), cfg)
        toks = _tokens(cfg)
        a = M.forward(params, statics, cfg, toks)
        b = M.forward(params, statics, cfg_p, toks)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-5)


class TestSketchLayers:
    def test_net_output_shape(self):
        net = sketch_net_init(jax.random.PRNGKey(0), 16, 8)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 10, 16))
        y = sketch_net_apply(net, x)
        assert y.shape == (4, 10, 8)

    @pytest.mark.parametrize("p", [2, 4, 8])
    def test_nets_per_degree(self, p):
        nets = learnable_sketch_init(jax.random.PRNGKey(0), 16, 8, p)
        assert len(nets) == max(p - 2, 0)

    def test_half_sketch_bounded_by_tanh(self):
        # Output of the learnable half sketch is within +-sqrt(r).
        r, p = 8, 4
        nets = learnable_sketch_init(jax.random.PRNGKey(0), 16, r, p)
        x = 10.0 * jax.random.normal(jax.random.PRNGKey(1), (32, 16))
        y = np.asarray(learnable_half_sketch(nets, x, r, p))
        assert np.all(np.abs(y) <= np.sqrt(r) + 1e-5)

    def test_param_count_formula(self):
        # ~ (p-2) * (8hr + 24r^2) weights, Appendix D.
        h, r, p = 64, 32, 4
        weights_only = (p - 2) * (8 * h * r + 24 * r * r)
        got = param_count(h, r, p)
        assert weights_only <= got <= weights_only + (p - 2) * (18 * r + 2 * r)


class TestTrain:
    def test_loss_decreases(self):
        cfg = _cfg(attn="polysketch", degree=4, sketch_size=8,
                   sketch_mode="learned", local_exact=True)
        tc = T.TrainConfig(peak_lr=3e-3, warmup_steps=2, total_steps=60)
        params, statics = M.init(jax.random.PRNGKey(0), cfg)
        opt = T.init_opt_state(params)
        step = jax.jit(T.make_train_step(cfg, tc))
        toks = _tokens(cfg, batch=4, extra=1)   # overfit one batch
        losses = []
        for _ in range(30):
            params, opt, loss = step(params, statics, opt, toks)
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.5, losses

    def test_lr_schedule_shape(self):
        tc = T.TrainConfig(peak_lr=1.0, warmup_steps=10, total_steps=100)
        lrs = [float(T.lr_at(tc, jnp.asarray(s))) for s in (0, 5, 10, 55, 100)]
        assert lrs[0] == 0.0
        assert abs(lrs[1] - 0.5) < 1e-6
        assert abs(lrs[2] - 1.0) < 1e-6
        assert 0.0 < lrs[3] < 1.0
        assert lrs[4] == 0.0

    def test_grad_clip_bounds_update(self):
        tc = T.TrainConfig(grad_clip=1e-9)   # essentially freeze
        params = {"w": jnp.ones((4,))}
        grads = {"w": 1e6 * jnp.ones((4,))}
        opt = T.init_opt_state(params)
        new_p, _ = T.adam_update(tc, params, grads, opt)
        assert float(jnp.max(jnp.abs(new_p["w"] - params["w"]))) < 1e-3


class TestFlatTheta:
    def test_pack_unpack_roundtrip(self):
        cfg = MECHS[2]
        params, _ = M.init(jax.random.PRNGKey(0), cfg)
        theta = aot.pack(params)
        unpack = aot.make_unpack(params)
        back = unpack(theta)
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_flatten_spec_offsets_contiguous(self):
        cfg = MECHS[0]
        params, _ = M.init(jax.random.PRNGKey(0), cfg)
        spec, total = aot.flatten_spec(params)
        off = 0
        for name, shape, o in spec:
            assert o == off
            size = 1
            for d in shape:
                size *= d
            off += size
        assert off == total

    def test_forward_via_flat_theta_matches(self):
        cfg = MECHS[0]
        params, statics = M.init(jax.random.PRNGKey(0), cfg)
        theta = aot.pack(params)
        unpack = aot.make_unpack(params)
        toks = _tokens(cfg)
        a = M.forward(params, statics, cfg, toks)
        b = M.forward(unpack(theta), statics, cfg, toks)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
