"""Hypothesis property sweeps over the Pallas kernels' shape/parameter
space, asserting against the pure-jnp oracles (ref.py).

The deterministic pytest suite pins a handful of shapes; these sweeps let
hypothesis explore (n, h, block, p, r) jointly — shrinkage gives a minimal
failing configuration if a kernel has a shape-dependent bug.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.common import layernorm
from compile.kernels import ref, sketch
from compile.kernels.pallas import (linear_attention_pallas,
                                    poly_attention_pallas,
                                    polysketch_attention_pallas,
                                    softmax_attention_pallas)

jax.config.update("jax_enable_x64", False)

# interpret-mode Pallas is slow: keep examples small and few.
COMMON = dict(max_examples=12, deadline=None)


def rand(seed, *shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * scale


def divisors_of(n):
    return [b for b in (8, 16, 32, 64) if n % b == 0]


@st.composite
def attn_shapes(draw):
    n = draw(st.sampled_from([16, 32, 48, 64, 128]))
    h = draw(st.sampled_from([4, 8, 16, 32]))
    block = draw(st.sampled_from(divisors_of(n)))
    seed = draw(st.integers(0, 2**31 - 1))
    return n, h, block, seed


@settings(**COMMON)
@given(attn_shapes())
def test_softmax_pallas_matches_oracle_sweep(shape):
    n, h, block, seed = shape
    q, k, v = rand(seed, n, h), rand(seed + 1, n, h), rand(seed + 2, n, h)
    got = softmax_attention_pallas(q, k, v, block_q=block, block_k=block)
    want = ref.softmax_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


@settings(**COMMON)
@given(attn_shapes(), st.sampled_from([2, 4, 8]))
def test_poly_pallas_matches_oracle_sweep(shape, p):
    n, h, block, seed = shape
    q, k, v = rand(seed, n, h), rand(seed + 1, n, h), rand(seed + 2, n, h)
    got = poly_attention_pallas(q, k, v, p=p, block_q=block, block_k=block)
    want = ref.poly_attention(q, k, v, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-4)


@settings(**COMMON)
@given(attn_shapes())
def test_linear_pallas_matches_oracle_sweep(shape):
    n, f, block, seed = shape
    h = 8
    # Positive features (performer-style) keep the denominator well away
    # from zero so the comparison is numerically meaningful.
    phi_q = jnp.abs(rand(seed, n, f)) + 0.1
    phi_k = jnp.abs(rand(seed + 1, n, f)) + 0.1
    v = rand(seed + 2, n, h)
    got = linear_attention_pallas(phi_q, phi_k, v, block=block)
    want = ref.linear_attention(phi_q, phi_k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-4)


@settings(**COMMON)
@given(attn_shapes(), st.sampled_from([4, 8, 16]), st.booleans())
def test_polysketch_pallas_matches_scan_sweep(shape, r, local):
    # The Pallas block kernel must agree with the jnp scan implementation
    # for any (shape, sketch size, local-exact) combination.
    from compile.kernels.linear_attn import block_polysketch_attention
    n, h, block, seed = shape
    p = 4
    key = jax.random.PRNGKey(seed)
    q, k, v = rand(seed, n, h), rand(seed + 1, n, h), rand(seed + 2, n, h)
    qn, kn = layernorm(q), layernorm(k)
    gs = sketch.sample_projections(key, h, r, p)
    lh = sketch.half_sketch(qn, gs, r, p)
    rh = sketch.half_sketch(kn, gs, r, p)
    got = polysketch_attention_pallas(lh, rh, v, block=block, q=q, k=k, p=p,
                                      local_exact=local)
    want = block_polysketch_attention(lh, rh, v, block, q=q, k=k, p=p,
                                      local_exact=local)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-4)


@settings(**COMMON)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4, 8]),
       st.sampled_from([4, 8, 16, 32]))
def test_nonnegative_sketch_property_sweep(seed, p, r):
    # Theorem 1.1 property 1: every sketched attention weight >= 0, for any
    # seed/degree/sketch-size (up to fp cancellation noise, which scales
    # with the weight magnitude ~ ||q||^p ||k||^p).
    q = layernorm(rand(seed, 24, 8))
    k = layernorm(rand(seed + 1, 24, 8))
    key = jax.random.PRNGKey(seed + 2)
    gs = sketch.sample_projections(key, 8, r, p)
    phi_q = sketch.polysketch_nonnegative(q, gs, r, p)
    phi_k = sketch.polysketch_nonnegative(k, gs, r, p)
    w = np.asarray(phi_q @ phi_k.T)
    floor = -1e-5 * float(np.abs(w).max() + 1.0)
    assert w.min() >= floor, f"negative weight {w.min()} (floor {floor})"


@settings(**COMMON)
@given(st.integers(0, 2**31 - 1))
def test_block_linear_attention_block_invariance_sweep(seed):
    # Section 3.1: the blocked schedule must be block-size invariant —
    # identical outputs (up to fp reassociation) for every block size.
    from compile.kernels.linear_attn import block_linear_attention
    phi_q = jnp.abs(rand(seed, 64, 8)) + 0.1
    phi_k = jnp.abs(rand(seed + 1, 64, 8)) + 0.1
    v = rand(seed + 2, 64, 4)
    want = ref.linear_attention(phi_q, phi_k, v)
    for blk in (8, 16, 32, 64):
        got = block_linear_attention(phi_q, phi_k, v, blk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)
