"""L2: Transformer++ language model (Appendix I recipe).

Decoder-only, causal, with the attention mechanism pluggable per config:
softmax | polynomial(p) | polysketch(random|learned, +-local, r) | performer.

Recipe (Appendix I): sinusoidal absolute position embeddings added to the
input embeddings, RoPE at every attention head, pre-LN blocks, GLU
feed-forward with expansion factor 4 and GELU, tied input/output embedding.

Everything is functional: ``init(key, cfg)`` builds two pytrees —
``params`` (trained) and ``statics`` (constants: sinusoidal table, random
sketch projections, performer features) — and ``forward(params, statics,
cfg, tokens)`` returns logits.  ``jax.jit`` of these functions is lowered to
HLO text by aot.py; the rust runtime replays them without Python.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, asdict
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import gelu, layernorm, LN_EPS
from .kernels import sketch
from .kernels.linear_attn import (block_linear_attention,
                                  block_polysketch_attention)
from .kernels.ref import (performer_features, poly_attention,
                          softmax_attention)
from .sketch_layers import learnable_half_sketch, learnable_sketch_init


@dataclass(frozen=True)
class ModelConfig:
    """Architecture + attention-mechanism configuration."""
    vocab: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    head_dim: int = 32
    ffn_mult: int = 4
    ctx: int = 256
    attn: str = "polysketch"        # softmax | poly | polysketch | performer
    degree: int = 4                 # p, for poly / polysketch
    sketch_size: int = 16           # r
    sketch_mode: str = "learned"    # learned | random
    local_exact: bool = True        # Section 3.2 local exact attention
    block: int = 64                 # b, block-lt block size
    performer_features: int = 64    # m, for performer
    use_pallas: bool = False        # route fwd attention through Pallas kernels

    def name(self) -> str:
        if self.attn == "softmax":
            mech = "softmax"
        elif self.attn == "poly":
            mech = f"poly{self.degree}"
        elif self.attn == "polysketch":
            mech = (f"psk{self.degree}_r{self.sketch_size}_{self.sketch_mode}"
                    + ("_local" if self.local_exact else ""))
        elif self.attn == "performer":
            mech = f"performer{self.performer_features}"
        else:
            raise ValueError(self.attn)
        return (f"{mech}_v{self.vocab}_d{self.d_model}_l{self.n_layers}"
                f"_h{self.n_heads}x{self.head_dim}_c{self.ctx}")

    def flat(self) -> Dict[str, object]:
        return asdict(self)


# ------------------------------------------------------------------ init

def _dense(key, din, dout, scale=None):
    if scale is None:
        scale = 1.0 / math.sqrt(din)
    return jax.random.normal(key, (din, dout), jnp.float32) * scale


def sinusoidal_table(n: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2.0 * i / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def rope_tables(n: int, hd: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    i = jnp.arange(hd // 2, dtype=jnp.float32)[None, :]
    theta = pos / jnp.power(10000.0, 2.0 * i / hd)
    return jnp.cos(theta), jnp.sin(theta)


def init(key: jax.Array, cfg: ModelConfig) -> Tuple[Dict, Dict]:
    """Build (params, statics)."""
    d, hd, nh = cfg.d_model, cfg.head_dim, cfg.n_heads
    inner = nh * hd
    keys = jax.random.split(key, 2 + cfg.n_layers)

    params: Dict = {
        "tok_emb": jax.random.normal(keys[0], (cfg.vocab, d), jnp.float32) * 0.02,
        "ln_f": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
        "layers": [],
    }
    statics: Dict = {
        "pos": sinusoidal_table(cfg.ctx, d),
        "rope_cos": rope_tables(cfg.ctx, hd)[0],
        "rope_sin": rope_tables(cfg.ctx, hd)[1],
    }

    for li in range(cfg.n_layers):
        lk = jax.random.split(keys[2 + li], 10)
        layer = {
            "ln1": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
            "wq": _dense(lk[0], d, inner),
            "wk": _dense(lk[1], d, inner),
            "wv": _dense(lk[2], d, inner),
            "wo": _dense(lk[3], inner, d, scale=1.0 / math.sqrt(inner * 2 * cfg.n_layers)),
            "ln2": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
            "ffn_gate": _dense(lk[4], d, cfg.ffn_mult * d),
            "ffn_up": _dense(lk[5], d, cfg.ffn_mult * d),
            "ffn_down": _dense(lk[6], cfg.ffn_mult * d, d,
                               scale=1.0 / math.sqrt(cfg.ffn_mult * d * 2 * cfg.n_layers)),
        }
        if cfg.attn == "polysketch" and cfg.sketch_mode == "learned":
            layer["sketch"] = learnable_sketch_init(lk[7], hd, cfg.sketch_size,
                                                    cfg.degree)
        params["layers"].append(layer)

        if cfg.attn == "polysketch" and cfg.sketch_mode == "random":
            statics[f"sketch{li}"] = sketch.sample_projections(
                lk[8], hd, cfg.sketch_size, cfg.degree)
        if cfg.attn == "performer":
            # Orthogonalized Gaussian features (FAVOR+).
            w = jax.random.normal(lk[9], (hd, cfg.performer_features), jnp.float32)
            qmat, _ = jnp.linalg.qr(jax.random.normal(lk[9], (max(hd, cfg.performer_features),) * 2))
            w = qmat[:hd, :cfg.performer_features] * math.sqrt(hd)
            statics[f"performer{li}"] = w

    return params, statics


def num_params(params) -> int:
    return int(sum(x.size for x in jax.tree_util.tree_leaves(params)))


# ------------------------------------------------------------------ fwd

def _ln(x, g):
    return layernorm(x) * g["scale"] + g["bias"]


def _rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, n, H, hd); rotate-half RoPE."""
    n = x.shape[1]
    cos, sin = cos[:n][None, :, None, :], sin[:n][None, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention(params_l: Dict, statics: Dict, cfg: ModelConfig, li: int,
               x: jnp.ndarray) -> jnp.ndarray:
    """Multi-head attention of one layer; x: (B, n, d) pre-normed input."""
    B, n, d = x.shape
    nh, hd = cfg.n_heads, cfg.head_dim

    q = (x @ params_l["wq"]).reshape(B, n, nh, hd)
    k = (x @ params_l["wk"]).reshape(B, n, nh, hd)
    v = (x @ params_l["wv"]).reshape(B, n, nh, hd)
    q = _rope(q, statics["rope_cos"], statics["rope_sin"])
    k = _rope(k, statics["rope_cos"], statics["rope_sin"])

    if cfg.attn == "softmax":
        f = jax.vmap(jax.vmap(lambda q_, k_, v_: softmax_attention(q_, k_, v_),
                              in_axes=1, out_axes=1))
        out = f(q, k, v)
    elif cfg.attn == "poly":
        f = jax.vmap(jax.vmap(
            lambda q_, k_, v_: poly_attention(q_, k_, v_, cfg.degree),
            in_axes=1, out_axes=1))
        out = f(q, k, v)
    elif cfg.attn == "polysketch":
        qn, kn = layernorm(q), layernorm(k)
        if cfg.sketch_mode == "learned":
            nets = params_l["sketch"]
            L = learnable_half_sketch(nets, qn, cfg.sketch_size, cfg.degree)
            R = learnable_half_sketch(nets, kn, cfg.sketch_size, cfg.degree)
        else:
            gs = statics[f"sketch{li}"]
            L = sketch.half_sketch(qn, gs, cfg.sketch_size, cfg.degree)
            R = sketch.half_sketch(kn, gs, cfg.sketch_size, cfg.degree)

        block = min(cfg.block, n)

        def one_head(l_, r_, v_, q_, k_):
            if cfg.use_pallas:
                from .kernels.pallas import polysketch_attention_pallas
                return polysketch_attention_pallas(
                    l_, r_, v_, block=block,
                    q=q_ if cfg.local_exact else None,
                    k=k_ if cfg.local_exact else None,
                    p=cfg.degree, local_exact=cfg.local_exact)
            return block_polysketch_attention(
                l_, r_, v_, block,
                q=q_ if cfg.local_exact else None,
                k=k_ if cfg.local_exact else None,
                p=cfg.degree, local_exact=cfg.local_exact)

        f = jax.vmap(jax.vmap(one_head, in_axes=1, out_axes=1))
        out = f(L, R, v, q, k)
    elif cfg.attn == "performer":
        w = statics[f"performer{li}"]
        block = min(cfg.block, n)

        def one_head(q_, k_, v_):
            pq = performer_features(q_, w)
            pk = performer_features(k_, w)
            if cfg.use_pallas:
                from .kernels.pallas import linear_attention_pallas
                return linear_attention_pallas(pq, pk, v_, block=block)
            return block_linear_attention(pq, pk, v_, block)

        f = jax.vmap(jax.vmap(one_head, in_axes=1, out_axes=1))
        out = f(q, k, v)
    else:
        raise ValueError(cfg.attn)

    return out.reshape(B, n, nh * hd) @ params_l["wo"]


def _ffn(params_l: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """GLU feed-forward (GEGLU): down(gelu(gate(x)) * up(x))."""
    return (gelu(x @ params_l["ffn_gate"]) * (x @ params_l["ffn_up"])) @ params_l["ffn_down"]


def forward(params: Dict, statics: Dict, cfg: ModelConfig,
            tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens: (B, n) int32 -> logits (B, n, vocab).

    Layers run under ``jax.lax.scan`` (stacked homogeneous params), so the
    lowered HLO contains ONE layer body regardless of depth — XLA backend
    compile time of the train graph was dominated by the unrolled layer
    stack (minutes for the learned-sketch models; see DESIGN.md §Perf).
    Set ``PSF_UNROLL_LAYERS=1`` to restore the unrolled form for A/B.
    """
    import os
    B, n = tokens.shape
    # Vaswani §3.4 embedding scaling: multiply embeddings by sqrt(d) before
    # adding the unit-scale sinusoidal table, otherwise the positional
    # signal (O(1)) drowns the 0.02-std token embeddings and training
    # plateaus (measured: 4x worse ppl at 300 steps without it).
    scale = math.sqrt(cfg.d_model)
    x = params["tok_emb"][tokens] * scale + statics["pos"][:n][None]

    if os.environ.get("PSF_UNROLL_LAYERS") == "1" or len(params["layers"]) == 1:
        for li, layer in enumerate(params["layers"]):
            x = x + _attention(layer, statics, cfg, li, _ln(x, layer["ln1"]))
            x = x + _ffn(layer, _ln(x, layer["ln2"]))
    else:
        # Stack per-layer params (and per-layer statics) along a new axis 0.
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *params["layers"])
        per_layer_statics = _stack_layer_statics(statics, cfg,
                                                 len(params["layers"]))

        def body(x, layer_and_statics):
            layer, lstat = layer_and_statics
            # Merge shared statics (pos/rope) with this layer's slice.
            merged = {**statics, **lstat}
            x = x + _attention(layer, merged, cfg, 0, _ln(x, layer["ln1"]))
            x = x + _ffn(layer, _ln(x, layer["ln2"]))
            return x, None

        x, _ = jax.lax.scan(body, x, (stacked, per_layer_statics))

    x = _ln(x, params["ln_f"])
    return x @ params["tok_emb"].T     # tied embedding


def _stack_layer_statics(statics: Dict, cfg: ModelConfig, n_layers: int) -> Dict:
    """Stack the per-layer statics (random sketches / performer features)
    into scan-compatible arrays keyed as layer 0 expects them."""
    out: Dict = {}
    if cfg.attn == "polysketch" and cfg.sketch_mode == "random":
        per = [statics[f"sketch{li}"] for li in range(n_layers)]
        out["sketch0"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)
    if cfg.attn == "performer":
        out["performer0"] = jnp.stack(
            [statics[f"performer{li}"] for li in range(n_layers)])
    return out


def loss_fn(params: Dict, statics: Dict, cfg: ModelConfig,
            tokens: jnp.ndarray) -> jnp.ndarray:
    """Next-token cross entropy; tokens: (B, n+1) int32.

    Masking convention (shared with the rust task generators):
      * id 0 is PAD — contributes no loss as a target;
      * a NEGATIVE id is visible as an input (abs value) but masked as a
        target.  The LM corpus uses only positive ids (loss everywhere);
        the synthetic tasks negate everything except answer positions so
        the loss trains exactly the task signal (Appendix F protocol).
    """
    raw_in, raw_tgt = tokens[:, :-1], tokens[:, 1:]
    inputs = jnp.abs(raw_in)
    targets = jnp.abs(raw_tgt)
    mask = (raw_tgt > 0).astype(jnp.float32)
    logits = forward(params, statics, cfg, inputs)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum((logz - gold) * mask) / denom
