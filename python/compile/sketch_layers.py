"""Algorithm 2: learnable polynomial sketches (Appendix D).

Each random projection G of Algorithm 1 is replaced by a small dense network
f(.) of comparable size: output dim r, three hidden layers [8r, r, 8r], GELU
after hidden layers 1 and 3, layer normalization before the input and before
hidden layer 2 — ~8hr + 24r^2 parameters per net, (p-2) nets per attention
layer, shared across all heads of the layer (Section 4, "all attention heads
share the same phi' within the same attention layer").

The combine step applies the paper's tanh trick:
    sqrt(r) * tanh( sqrt(1/r) * (f1(M1) * f2(M2)) )
keeping outputs in a bounded range so optimization stays stable.
"""

from __future__ import annotations

import math
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp

from .common import gelu, layernorm
from .kernels.sketch import num_projections, projection_shapes


def _dense_init(key: jax.Array, din: int, dout: int) -> Dict[str, jnp.ndarray]:
    w = jax.random.normal(key, (din, dout), jnp.float32) / math.sqrt(din)
    return {"w": w, "b": jnp.zeros((dout,), jnp.float32)}


def sketch_net_init(key: jax.Array, din: int, r: int) -> Dict[str, Dict]:
    """Parameters of one learnable-projection net f: R^din -> R^r."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "h1": _dense_init(k1, din, 8 * r),
        "h2": _dense_init(k2, 8 * r, r),
        "h3": _dense_init(k3, r, 8 * r),
        "out": _dense_init(k4, 8 * r, r),
    }


def sketch_net_apply(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """f(x): LN -> Dense(8r) -> GELU -> LN -> Dense(r) -> Dense(8r) -> GELU -> Dense(r)."""
    x = layernorm(x)
    x = gelu(x @ params["h1"]["w"] + params["h1"]["b"])
    x = layernorm(x)
    x = x @ params["h2"]["w"] + params["h2"]["b"]
    x = gelu(x @ params["h3"]["w"] + params["h3"]["b"])
    return x @ params["out"]["w"] + params["out"]["b"]


def learnable_sketch_init(key: jax.Array, h: int, r: int, p: int) -> List[Dict]:
    """One net per Gaussian that PolySketchWithNegativity(., r, p/2) consumes.

    Net input dims follow projection_shapes: h at the leaves, r above.
    """
    shapes = projection_shapes(h, r, p // 2)
    keys = jax.random.split(key, max(len(shapes), 1))
    return [sketch_net_init(kk, din, r) for kk, (din, _) in zip(keys, shapes)]


def learnable_half_sketch(nets: Sequence[Dict], x: jnp.ndarray,
                          r: int, p: int) -> jnp.ndarray:
    """LearnablePolySketchWithNegativity(x, r, p/2) — the half sketch L.

    The full non-negative feature map is the row-wise self-tensor of the
    result (applied implicitly by the block attention kernels).
    """
    return _learnable_pswn(nets, x, r, p // 2)


def _learnable_pswn(nets: Sequence[Dict], x: jnp.ndarray, r: int, d: int) -> jnp.ndarray:
    if d == 1:
        return x
    n_sub = num_projections(d // 2)
    m1 = _learnable_pswn(nets[:n_sub], x, r, d // 2)
    m2 = _learnable_pswn(nets[n_sub:2 * n_sub], x, r, d // 2)
    f1, f2 = nets[2 * n_sub], nets[2 * n_sub + 1]
    y = math.sqrt(1.0 / r) * (sketch_net_apply(f1, m1) * sketch_net_apply(f2, m2))
    return math.sqrt(float(r)) * jnp.tanh(y)


def param_count(h: int, r: int, p: int) -> int:
    """Approximate parameter count added per attention layer (for docs)."""
    total = 0
    for din, _ in projection_shapes(h, r, p // 2):
        total += din * 8 * r + 8 * r * r + r * 8 * r + 8 * r * r  # weights
        total += 8 * r + r + 8 * r + r                            # biases
    return total
