"""AOT pipeline: lower every artifact the rust coordinator needs to HLO text.

Interchange is HLO *text* (xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id
serialized protos; the text parser reassigns ids — see /opt/xla-example).

Calling convention (the rust side mirrors this in runtime/):

  Every executable has a SINGLE non-tuple array root: xla_extension 0.5.1
  crashes when transferring tuple literals (ShapeUtil::ByteSizeOf of a tuple
  shape needs a pointer size the CPU client does not set), so model state is
  fused into one flat f32 "state" vector of size S = 3P + 2 laid out as

      state = [ theta (P) | m (P) | v (P) | step | loss ]

  and the artifacts are

  train    : [state (S,) f32, tokens (B, T+1) s32] -> state' (S,)
  stats    : [state (S,) f32]                      -> (2,) f32  [step, loss]
  evalloss : [state (S,) f32, tokens (B, T+1) s32] -> (1,) f32  mean NLL
  fwd      : [state (S,) f32, tokens (B, T) s32]   -> logits (B, T, V)
  grads    : [state (S,) f32, tokens (B, T+1) s32] -> (P+1,) f32 [grad|loss]
  gradstep : [state (S,) f32, grads (P+1,) f32]    -> state' (S,)
  attn     : [q (H,n,hd), k (H,n,hd), v (H,n,hd)]  -> out (H,n,hd)

`train` fuses grads+gradstep for the single-worker hot loop; the grads /
gradstep pair factors the step so the rust coordinator can average gradients
across simulated data-parallel workers (and accumulate microbatches) before
applying one optimizer update — the paper's 32-TPU synchronous protocol.

The rust hot loop keeps `state` device-resident (the train output buffer is
fed straight back in) and reads the 8-byte `stats` output per step; packing /
unpacking of the parameter pytree happens inside the HLO.  Statics (position
tables, random sketches, performer features) are baked into the HLO as
constants.  `init.bin` holds the initial theta (P little-endian f32).

Usage:  python -m compile.aot --out ../artifacts [--preset all|models|micro|tasks]
"""

from __future__ import annotations

import argparse
import os
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T
from .common import layernorm
from .kernels import sketch


# ------------------------------------------------------------ lowering

def to_hlo_text(lowered) -> str:
    """HLO text with a single non-tuple root (see module docstring).

    print_large_constants=True is LOAD-BEARING: the default printer elides
    big literals as ``constant({...})`` and xla_extension 0.5.1's text
    parser silently reads the elision as ZEROS — every baked static (RoPE
    tables, positional tables, random sketches, performer features) came
    back zero, which nulled all polynomial/polysketch attention while
    leaving softmax models plausibly alive (exp(0) = uniform weights).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False)
    return comp.as_hlo_text(True)


def lower_to_file(fn, args, path: str) -> None:
    text = to_hlo_text(jax.jit(fn).lower(*args))
    with open(path, "w") as f:
        f.write(text)


# ------------------------------------------------------------ flat theta

def flatten_spec(params) -> Tuple[List[Tuple[str, Tuple[int, ...], int]], int]:
    """Leaf (path, shape, offset) list in jax tree order + total size."""
    leaves_with_path = jax.tree_util.tree_flatten_with_path(params)[0]
    spec, off = [], 0
    for path, leaf in leaves_with_path:
        name = jax.tree_util.keystr(path).replace(" ", "")
        spec.append((name, tuple(leaf.shape), off))
        off += leaf.size
    return spec, off


def pack(params) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(params)
    return jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])


def make_unpack(params):
    treedef = jax.tree_util.tree_structure(params)
    shapes = [l.shape for l in jax.tree_util.tree_leaves(params)]
    sizes = [int(jnp.prod(jnp.array(s))) if s else 1 for s in shapes]

    def unpack(theta: jnp.ndarray):
        out, off = [], 0
        for shape, size in zip(shapes, sizes):
            out.append(jax.lax.dynamic_slice(theta, (off,), (size,)).reshape(shape))
            off += size
        return jax.tree_util.tree_unflatten(treedef, out)

    return unpack


# ------------------------------------------------------------ model emit

def emit_model(cfg: M.ModelConfig, tc: T.TrainConfig, batch: int,
               out_dir: str, seed: int = 0, tag: str | None = None) -> str:
    """Emit train/stats/evalloss/fwd HLO + init.bin + manifest for one
    config, all with single-array roots (see module docstring)."""
    name = tag or cfg.name()
    params, statics = M.init(jax.random.PRNGKey(seed), cfg)
    spec, total = flatten_spec(params)
    unpack = make_unpack(params)
    step_fn = T.make_train_step(cfg, tc)
    eval_fn = T.make_eval_loss(cfg)
    P = total
    S = 3 * P + 2

    def split_state(state):
        theta, m, v = state[:P], state[P:2 * P], state[2 * P:3 * P]
        step = state[3 * P].astype(jnp.int32)
        return theta, m, v, step

    def train_flat(state, tokens):
        theta, m, v, step = split_state(state)
        p = unpack(theta)
        opt = {"m": unpack(m), "v": unpack(v), "step": step}
        new_p, new_opt, loss = step_fn(p, statics, opt, tokens)
        return jnp.concatenate([
            pack(new_p), pack(new_opt["m"]), pack(new_opt["v"]),
            new_opt["step"].astype(jnp.float32)[None], loss[None]])

    def stats_flat(state):
        return state[3 * P:]

    def evalloss_flat(state, tokens):
        theta = state[:P]
        return eval_fn(unpack(theta), statics, tokens)[None]

    def fwd_flat(state, tokens):
        theta = state[:P]
        return M.forward(unpack(theta), statics, cfg, tokens)

    def grads_flat(state, tokens):
        theta = state[:P]
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, statics, cfg, tokens))(unpack(theta))
        return jnp.concatenate([pack(grads), loss[None]])

    def gradstep_flat(state, gradvec):
        theta, m, v, step = split_state(state)
        p = unpack(theta)
        opt = {"m": unpack(m), "v": unpack(v), "step": step}
        grads = unpack(gradvec[:P])
        new_p, new_opt = T.adam_update(tc, p, grads, opt)
        return jnp.concatenate([
            pack(new_p), pack(new_opt["m"]), pack(new_opt["v"]),
            new_opt["step"].astype(jnp.float32)[None], gradvec[P:]])

    state_s = jax.ShapeDtypeStruct((S,), jnp.float32)
    grad_s = jax.ShapeDtypeStruct((P + 1,), jnp.float32)
    tok_tr = jax.ShapeDtypeStruct((batch, cfg.ctx + 1), jnp.int32)
    tok_fw = jax.ShapeDtypeStruct((batch, cfg.ctx), jnp.int32)

    files = {
        "train": f"{name}.train.hlo.txt",
        "stats": f"{name}.stats.hlo.txt",
        "evalloss": f"{name}.evalloss.hlo.txt",
        "fwd": f"{name}.fwd.hlo.txt",
        "grads": f"{name}.grads.hlo.txt",
        "gradstep": f"{name}.gradstep.hlo.txt",
        "init": f"{name}.init.bin",
    }
    lower_to_file(train_flat, (state_s, tok_tr),
                  os.path.join(out_dir, files["train"]))
    lower_to_file(stats_flat, (state_s,),
                  os.path.join(out_dir, files["stats"]))
    lower_to_file(evalloss_flat, (state_s, tok_tr),
                  os.path.join(out_dir, files["evalloss"]))
    lower_to_file(fwd_flat, (state_s, tok_fw),
                  os.path.join(out_dir, files["fwd"]))
    lower_to_file(grads_flat, (state_s, tok_tr),
                  os.path.join(out_dir, files["grads"]))
    lower_to_file(gradstep_flat, (state_s, grad_s),
                  os.path.join(out_dir, files["gradstep"]))

    import numpy as np
    np.asarray(pack(params)).astype("<f4").tofile(os.path.join(out_dir, files["init"]))

    man = [f"psf-manifest v1", f"name {name}", "kind model"]
    for k, v in cfg.flat().items():
        man.append(f"cfg {k} {_fmt(v)}")
    for k, v in tc.flat().items():
        man.append(f"tc {k} {_fmt(v)}")
    man.append(f"batch {batch}")
    man.append(f"nparams {total}")
    for leafname, shape, off in spec:
        dims = "x".join(str(d) for d in shape) if shape else "scalar"
        man.append(f"leaf {leafname} {off} {dims}")
    for k, v in files.items():
        man.append(f"file {k} {v}")
    with open(os.path.join(out_dir, f"{name}.manifest.txt"), "w") as f:
        f.write("\n".join(man) + "\n")
    print(f"  model {name}: P={total} ({total * 4 / 1e6:.2f} MB params)")
    return name


def _fmt(v) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    return str(v)


# ------------------------------------------------------------ micro emit

def emit_attn_micro(mech: str, n: int, out_dir: str, heads: int = 4,
                    hd: int = 32, rs: int = 16, p: int = 4, block: int = 64,
                    feat: int = 64, use_pallas: bool = True,
                    seed: int = 0) -> str:
    """Standalone attention-op artifact for latency benches (Fig 1/4, Tab 4).

    The Pallas-backed variants prove the L1 kernel -> HLO -> rust path.
    """
    from .kernels.linear_attn import (block_linear_attention,
                                      block_polysketch_attention)
    from .kernels.ref import performer_features
    key = jax.random.PRNGKey(seed)
    b = min(block, n)

    if mech == "softmax":
        if use_pallas:
            from .kernels.pallas import softmax_attention_pallas
            bq = min(64, n)
            one = lambda q, k, v: softmax_attention_pallas(q, k, v, block_q=bq,
                                                           block_k=bq)
        else:
            from .kernels.ref import softmax_attention as one
    elif mech == "poly":
        if use_pallas:
            from .kernels.pallas import poly_attention_pallas
            bq = min(64, n)
            one = lambda q, k, v: poly_attention_pallas(q, k, v, p=p, block_q=bq,
                                                        block_k=bq)
        else:
            from .kernels.ref import poly_attention
            one = lambda q, k, v: poly_attention(q, k, v, p)
    elif mech == "polysketch":
        gs = sketch.sample_projections(key, hd, rs, p)

        def one(q, k, v):
            qn, kn = layernorm(q), layernorm(k)
            l = sketch.half_sketch(qn, gs, rs, p)
            r = sketch.half_sketch(kn, gs, rs, p)
            if use_pallas:
                from .kernels.pallas import polysketch_attention_pallas
                return polysketch_attention_pallas(l, r, v, block=b, q=q, k=k,
                                                   p=p, local_exact=True)
            return block_polysketch_attention(l, r, v, b, q=q, k=k, p=p,
                                              local_exact=True)
    elif mech == "performer":
        w = jax.random.normal(key, (hd, feat), jnp.float32)

        def one(q, k, v):
            pq, pk = performer_features(q, w), performer_features(k, w)
            if use_pallas:
                from .kernels.pallas import linear_attention_pallas
                return linear_attention_pallas(pq, pk, v, block=b)
            return block_linear_attention(pq, pk, v, b)
    else:
        raise ValueError(mech)

    fn = jax.vmap(one)
    s = jax.ShapeDtypeStruct((heads, n, hd), jnp.float32)
    suffix = "pallas" if use_pallas else "scan"
    fname = f"attn_{mech}_{suffix}_n{n}.hlo.txt"
    lower_to_file(fn, (s, s, s), os.path.join(out_dir, fname))

    man = ["psf-manifest v1", f"name attn_{mech}_{suffix}_n{n}", "kind attn",
           f"cfg mech {mech}", f"cfg impl {suffix}", f"cfg n {n}",
           f"cfg heads {heads}", f"cfg head_dim {hd}", f"cfg sketch_size {rs}",
           f"cfg degree {p}", f"cfg block {b}", f"file attn {fname}"]
    with open(os.path.join(out_dir, f"attn_{mech}_{suffix}_n{n}.manifest.txt"),
              "w") as f:
        f.write("\n".join(man) + "\n")
    print(f"  attn {mech}/{suffix} n={n}")
    return fname


# ------------------------------------------------------------ presets

TC_DEFAULT = T.TrainConfig(peak_lr=3e-4, warmup_steps=60, total_steps=600,
                           beta1=0.95, beta2=0.98, weight_decay=0.01)
TC_TASK = T.TrainConfig(peak_lr=1e-3, warmup_steps=100, total_steps=2000,
                        beta1=0.9, beta2=0.98, weight_decay=0.0)

# GPT-2-small-style scaled to the CPU testbed (DESIGN.md §4 substitutions):
# layer count kept at a meaningful depth, widths shrunk.
LM = dict(vocab=512, d_model=128, n_layers=4, n_heads=4, head_dim=32, ctx=256)
LM_BATCH = 8

# App F synthetic-task model: 2 layers, 8 heads of size 16.
TASK = dict(d_model=128, n_layers=2, n_heads=8, head_dim=16)


def _lm_mechs(base: Dict) -> List[M.ModelConfig]:
    return [
        M.ModelConfig(**base, attn="softmax"),
        M.ModelConfig(**base, attn="poly", degree=4),
        M.ModelConfig(**base, attn="poly", degree=8),
        M.ModelConfig(**base, attn="polysketch", degree=4, sketch_size=16,
                      sketch_mode="learned", local_exact=True),
        M.ModelConfig(**base, attn="polysketch", degree=4, sketch_size=16,
                      sketch_mode="learned", local_exact=False),
        M.ModelConfig(**base, attn="polysketch", degree=4, sketch_size=16,
                      sketch_mode="random", local_exact=True),
        M.ModelConfig(**base, attn="polysketch", degree=4, sketch_size=8,
                      sketch_mode="learned", local_exact=True),
        M.ModelConfig(**base, attn="performer", performer_features=64),
    ]


def model_presets() -> List[Tuple[M.ModelConfig, T.TrainConfig, int, str | None]]:
    """Base suite at ctx 256 plus the Fig-2 context sweep.

    The Fig-2 sweep keeps the token budget per step fixed (the paper's "1M
    tokens per batch" protocol, scaled): batch x ctx = 2048 tokens at every
    context length, mirroring how the paper compares mechanisms.
    """
    out = [(cfg, TC_DEFAULT, LM_BATCH, None) for cfg in _lm_mechs(LM)]
    # Context sweep for Fig 2 / Tables 2-3 (base suite covers ctx=256).
    for ctx in (64, 128):
        batch = 2048 // ctx
        base = {**LM, "ctx": ctx, "block": min(64, ctx)}
        sweep = [c for c in _lm_mechs(base)
                 if c.attn in ("softmax", "performer")
                 or (c.attn == "poly" and c.degree == 4)
                 or (c.attn == "polysketch" and c.sketch_size == 16
                     and not (c.sketch_mode == "learned" and not c.local_exact))]
        out.extend((cfg, TC_DEFAULT, batch, None) for cfg in sweep)
    return out


def task_presets() -> List[Tuple[M.ModelConfig, T.TrainConfig, int, str]]:
    out = []
    for mech_kw, mech_tag in [
        (dict(attn="softmax"), "softmax"),
        (dict(attn="poly", degree=4), "poly4"),
        (dict(attn="polysketch", degree=4, sketch_size=16,
              sketch_mode="learned", local_exact=True), "psk"),
    ]:
        out.append((M.ModelConfig(vocab=32, ctx=256, block=64, **TASK, **mech_kw),
                    TC_TASK, 16, f"copy_{mech_tag}"))
    for mech_kw, mech_tag in [
        (dict(attn="softmax"), "softmax"),
        (dict(attn="polysketch", degree=4, sketch_size=16,
              sketch_mode="learned", local_exact=True), "psk"),
    ]:
        out.append((M.ModelConfig(vocab=24, ctx=128, block=32, **TASK, **mech_kw),
                    TC_TASK, 16, f"induction_{mech_tag}"))
    return out


def tiny_presets() -> List[Tuple[M.ModelConfig, T.TrainConfig, int, str]]:
    """Second-scale artifacts for rust integration tests (tests/ compiles
    these in seconds; the real suite takes minutes per artifact)."""
    base = dict(vocab=64, d_model=32, n_layers=1, n_heads=2, head_dim=16,
                ctx=32, block=16)
    return [
        (M.ModelConfig(**base, attn="softmax"), TC_TASK, 2, "tiny_softmax"),
        (M.ModelConfig(**base, attn="polysketch", degree=4, sketch_size=8,
                       sketch_mode="learned", local_exact=True), TC_TASK, 2,
         "tiny_psk"),
        (M.ModelConfig(**base, attn="polysketch", degree=4, sketch_size=8,
                       sketch_mode="random", local_exact=True), TC_TASK, 2,
         "tiny_psk_random"),
    ]


def micro_presets() -> List[Dict]:
    out = []
    for n in (128, 256, 512, 1024):
        out.append(dict(mech="softmax", n=n, use_pallas=True))
        out.append(dict(mech="poly", n=n, use_pallas=True))
        out.append(dict(mech="polysketch", n=n, use_pallas=True))
        out.append(dict(mech="performer", n=n, use_pallas=True))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default="all",
                    choices=["all", "models", "micro", "tasks", "tiny"])
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.preset in ("all", "models"):
        print("emitting model artifacts:")
        for cfg, tc, batch, tag in model_presets():
            emit_model(cfg, tc, batch, args.out, tag=tag)
    if args.preset in ("all", "tiny"):
        print("emitting tiny test artifacts:")
        for cfg, tc, batch, tag in tiny_presets():
            emit_model(cfg, tc, batch, args.out, tag=tag)
    if args.preset in ("all", "tasks"):
        print("emitting task artifacts:")
        for cfg, tc, batch, tag in task_presets():
            emit_model(cfg, tc, batch, args.out, tag=tag)
    if args.preset in ("all", "micro"):
        print("emitting attention micro artifacts:")
        for kw in micro_presets():
            emit_attn_micro(out_dir=args.out, **kw)
    print("done.")


if __name__ == "__main__":
    main()
