"""Training step: from-scratch Adam(W) + linear warmup/decay schedule.

No optax in this environment — the optimizer is implemented directly so the
whole train step (forward + backward + update + schedule) lowers to a single
HLO program the rust coordinator executes in a loop.

Paper recipe (Appendix G/I): Adam with weight decay, beta1 = 0.95,
beta2 = 0.98, linear warmup for the first fraction of steps then linear
decay to zero.
"""

from __future__ import annotations

from dataclasses import dataclass, asdict
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .model import ModelConfig, loss_fn


@dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    beta1: float = 0.95
    beta2: float = 0.98
    eps: float = 1e-9
    weight_decay: float = 0.01
    grad_clip: float = 1.0

    def flat(self) -> Dict[str, object]:
        return asdict(self)


def lr_at(tc: TrainConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup to peak_lr, then linear decay to 0 at total_steps."""
    step = step.astype(jnp.float32)
    warm = step / max(tc.warmup_steps, 1)
    decay = (tc.total_steps - step) / max(tc.total_steps - tc.warmup_steps, 1)
    return tc.peak_lr * jnp.clip(jnp.minimum(warm, decay), 0.0, 1.0)


def init_opt_state(params) -> Dict:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros,
            "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32)}


def _global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in leaves))


def adam_update(tc: TrainConfig, params, grads, opt_state) -> Tuple[Dict, Dict]:
    """One AdamW step with global-norm gradient clipping."""
    step = opt_state["step"] + 1
    gn = _global_norm(grads)
    scale = jnp.minimum(1.0, tc.grad_clip / (gn + 1e-12))
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    b1, b2 = tc.beta1, tc.beta2
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                               opt_state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                               opt_state["v"], grads)
    t = step.astype(jnp.float32)
    mhat_c = 1.0 / (1.0 - b1 ** t)
    vhat_c = 1.0 / (1.0 - b2 ** t)
    lr = lr_at(tc, step)

    def upd(p, m_, v_):
        u = (m_ * mhat_c) / (jnp.sqrt(v_ * vhat_c) + tc.eps)
        return p - lr * (u + tc.weight_decay * p)

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """Returns train_step(params, statics, opt_state, tokens) ->
    (params', opt_state', loss).  Suitable for jax.jit / AOT lowering."""

    def train_step(params, statics, opt_state, tokens):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, statics, cfg, tokens))(params)
        new_params, new_opt = adam_update(tc, params, grads, opt_state)
        return new_params, new_opt, loss

    return train_step


def make_eval_loss(cfg: ModelConfig):
    """Returns eval_loss(params, statics, tokens) -> mean NLL (perplexity =
    exp of this) over the batch."""

    def eval_loss(params, statics, tokens):
        return loss_fn(params, statics, cfg, tokens)

    return eval_loss
