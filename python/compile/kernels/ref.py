"""Pure-jnp oracles for every attention mechanism in the repo.

These are the *correctness* definitions: deliberately naive O(n^2)
implementations that materialize the full attention matrix.  All Pallas
kernels and all fast scan implementations are tested against these.

Shapes: single (batch, head) slice — q, k, v are (n, h).
"""

from __future__ import annotations

import jax.numpy as jnp

from ..common import layernorm


def causal_mask(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Lower-triangular (inclusive) mask of shape (n, n)."""
    return jnp.tril(jnp.ones((n, n), dtype=dtype))


def lt_mult(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Naive lt(A B^T) C — the operation Section 3.1 computes blockwise."""
    s = a @ b.T
    return (jnp.tril(s)) @ c


def softmax_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      causal: bool = True, scale: float | None = None) -> jnp.ndarray:
    """Vanilla softmax attention, numerically-stabilized (alpha = row max)."""
    n, h = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(h, q.dtype))
    s = (q @ k.T) * scale
    if causal:
        neg = jnp.asarray(-1e30, s.dtype)
        s = jnp.where(causal_mask(n, jnp.bool_), s, neg)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    w = jnp.exp(s)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return w @ v


def poly_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, p: int,
                   causal: bool = True, apply_ln: bool = True) -> jnp.ndarray:
    """Exact degree-p polynomial attention (Section 2.1).

    A_{ij} = <q'_i, k'_j>^p / (1 + sum_{j' <= i} <q'_i, k'_{j'}>^p)
    with q', k' layer-normalized.  The ``1 +`` in the denominator avoids
    division by zero (the paper's tweak).
    """
    if apply_ln:
        q, k = layernorm(q), layernorm(k)
    n, _ = q.shape
    s = (q @ k.T) ** p
    if causal:
        s = s * causal_mask(n, s.dtype)
    denom = 1.0 + jnp.sum(s, axis=-1, keepdims=True)
    return (s / denom) @ v


def linear_attention(phi_q: jnp.ndarray, phi_k: jnp.ndarray, v: jnp.ndarray,
                     causal: bool = True) -> jnp.ndarray:
    """Generic kernel-feature attention with the paper's 1+ denominator.

    Given feature-mapped queries/keys (n, r'), computes
    out_i = sum_{j<=i} <phi_q_i, phi_k_j> v_j / (1 + sum_{j<=i} <phi_q_i, phi_k_j>).
    """
    n = phi_q.shape[0]
    s = phi_q @ phi_k.T
    if causal:
        s = s * causal_mask(n, s.dtype)
    denom = 1.0 + jnp.sum(s, axis=-1, keepdims=True)
    return (s / denom) @ v


def polysketch_attention(l: jnp.ndarray, r: jnp.ndarray, v: jnp.ndarray,
                         q: jnp.ndarray | None = None,
                         k: jnp.ndarray | None = None,
                         p: int = 4,
                         block: int | None = None) -> jnp.ndarray:
    """Oracle for Polysketch attention with optional local exact blocks.

    l, r: degree-p/2 half-sketches of Q and K, shape (n, rs)  (outputs of
          PolySketchWithNegativity).  The implicit features are the row-wise
          self-tensors l^{(x)2}, r^{(x)2}, so attention weights are
          (l_i . r_j)^2 >= 0 (Theorem 2.4).
    q, k, p, block: if q/k are given and block is not None, pairs (i, j)
          falling in the same length-``block`` block use the exact polynomial
          weight <q'_i, k'_j>^p (Section 3.2) instead of the sketched one.
    """
    n = l.shape[0]
    s = (l @ r.T) ** 2
    if q is not None and block is not None:
        qn, kn = layernorm(q), layernorm(k)
        exact = (qn @ kn.T) ** p
        idx = jnp.arange(n) // block
        same = idx[:, None] == idx[None, :]
        s = jnp.where(same, exact, s)
    s = s * causal_mask(n, s.dtype)
    denom = 1.0 + jnp.sum(s, axis=-1, keepdims=True)
    return (s / denom) @ v


def performer_features(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """FAVOR+ positive random features: exp(w^T x - ||x||^2/2) / sqrt(m)."""
    m = w.shape[1]
    proj = x @ w
    sq = 0.5 * jnp.sum(jnp.square(x), axis=-1, keepdims=True)
    return jnp.exp(proj - sq) / jnp.sqrt(jnp.asarray(m, x.dtype))


def performer_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        w: jnp.ndarray, causal: bool = True) -> jnp.ndarray:
    """Performer baseline: positive-random-feature linear attention."""
    return linear_attention(performer_features(q, w), performer_features(k, w),
                            v, causal=causal)
