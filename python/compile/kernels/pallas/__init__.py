"""Pallas (interpret=True) kernels — the hand-scheduled L1 layer.

On a real TPU these lower to Mosaic; on this CPU testbed they run through
the Pallas interpreter, which preserves the block schedule (BlockSpec HBM<->
VMEM movement, carried scratch state) and the numerics, but not wallclock.
Correctness is asserted against ref.py; performance structure (tile shapes,
VMEM residency, MXU-shaped contractions) is documented in DESIGN.md §5.
"""

from .polysketch_attn import polysketch_attention_pallas
from .linear_attn import linear_attention_pallas
from .softmax_attn import softmax_attention_pallas
from .poly_attn import poly_attention_pallas

__all__ = [
    "polysketch_attention_pallas",
    "linear_attention_pallas",
    "softmax_attention_pallas",
    "poly_attention_pallas",
]
