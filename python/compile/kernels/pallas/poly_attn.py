"""Blocked exact degree-p polynomial attention Pallas kernel.

The quadratic-time baseline of Section 2.1 (Figure 2 "Polynomial").  Same
streaming structure as the flash softmax kernel, but no max-rescaling is
needed: after layer normalization the scores (q.k)^p are bounded and the
normalizer is the plain running sum 1 + sum_j (q_i . k_j)^p.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...common import layernorm


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, p: int):
    bq, h = q_ref.shape
    n = k_ref.shape[0]
    qi = pl.program_id(0)
    q = q_ref[...]

    s0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, h), jnp.float32)
    q_start = qi * bq
    num_kb = n // block_k

    def body(kb, carry):
        s, acc = carry
        k_start = kb * block_k
        kt = k_ref[pl.dslice(k_start, block_k), :]
        vt = v_ref[pl.dslice(k_start, block_k), :]
        sc = (q @ kt.T) ** p
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
        sc = jnp.where(rows >= cols, sc, 0.0)
        return s + jnp.sum(sc, axis=-1), acc + sc @ vt

    s, acc = jax.lax.fori_loop(0, jnp.minimum(qi + 1, num_kb), body, (s0, acc0))
    o_ref[...] = (acc / (1.0 + s)[:, None]).astype(o_ref.dtype)


def poly_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                          p: int = 4, block_q: int = 64, block_k: int = 64,
                          apply_ln: bool = True,
                          interpret: bool = True) -> jnp.ndarray:
    """Blocked causal degree-p polynomial attention; single slice."""
    n, h = q.shape
    if apply_ln:
        q, k = layernorm(q), layernorm(k)
    if n % block_q != 0 or n % block_k != 0:
        raise ValueError(f"n={n} not divisible by blocks ({block_q},{block_k})")
    return pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, p=p),
        grid=(n // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, h), lambda i: (i, 0)),
            pl.BlockSpec((n, h), lambda i: (0, 0)),
            pl.BlockSpec((n, h), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h), q.dtype),
        interpret=interpret,
    )(q, k, v)
