"""FlashAttention-style blocked causal softmax Pallas kernel.

The paper's speed baseline (Figures 1, 4; Table 4).  Structure mirrors the
JAX Pallas flash kernel: the grid walks query blocks; for each query block
the kernel streams key/value blocks up to the diagonal with the online
softmax recurrence (running row-max m and normalizer s rescaled per block),
so the n x n score matrix is never materialized — only (bq x bk) tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float):
    bq, h = q_ref.shape
    n = k_ref.shape[0]
    qi = pl.program_id(0)
    q = q_ref[...] * scale

    # Online-softmax carries: running max m, running sum s, accumulator acc.
    m0 = jnp.full((bq,), -1e30, jnp.float32)
    s0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, h), jnp.float32)

    q_start = qi * bq
    num_kb = n // block_k

    def body(kb, carry):
        m, s, acc = carry
        k_start = kb * block_k
        kt = k_ref[pl.dslice(k_start, block_k), :]
        vt = v_ref[pl.dslice(k_start, block_k), :]
        sc = q @ kt.T                                   # (bq, bk)
        # causal mask: query q_start+i attends to key k_start+j iff >=
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
        sc = jnp.where(rows >= cols, sc, -1e30)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[:, None])
        corr = jnp.exp(m - m_new)
        s_new = s * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + p @ vt
        return m_new, s_new, acc_new

    # Only key blocks at or before this query block can contribute.
    m, s, acc = jax.lax.fori_loop(0, jnp.minimum(qi + 1, num_kb), body,
                                  (m0, s0, acc0))
    o_ref[...] = (acc / s[:, None]).astype(o_ref.dtype)


def softmax_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                             block_q: int = 64, block_k: int = 64,
                             scale: float | None = None,
                             interpret: bool = True) -> jnp.ndarray:
    """Blocked causal softmax attention; single (batch, head) slice."""
    n, h = q.shape
    if scale is None:
        scale = float(1.0 / (h ** 0.5))
    if n % block_q != 0 or n % block_k != 0:
        raise ValueError(f"n={n} not divisible by blocks ({block_q},{block_k})")
    return pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, scale=scale),
        grid=(n // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, h), lambda i: (i, 0)),
            pl.BlockSpec((n, h), lambda i: (0, 0)),   # stream from full K
            pl.BlockSpec((n, h), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h), q.dtype),
        interpret=interpret,
    )(q, k, v)
