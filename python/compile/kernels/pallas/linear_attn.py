"""Generic causal linear attention Pallas kernel (block lt-multiplication).

Implements Section 3.1 for arbitrary feature maps: the grid walks the t =
n/b blocks in order; a VMEM scratch buffer carries the running prefix state
Z (f x (h+1)) — value columns and the denominator's ones-column fused so one
pass produces numerator and normalizer.  Per grid step the kernel does:

    out_l  = lt(phi_q_l phi_k_l^T) [V_l | 1]  +  phi_q_l Z      (b x (h+1))
    Z     +=      phi_k_l^T [V_l | 1]                           (f x (h+1))

which is exactly the paper's P_l + A_l Z_l decomposition.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(pq_ref, pk_ref, v_ref, o_ref, z_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    pq = pq_ref[...]                       # (b, f)
    pk = pk_ref[...]                       # (b, f)
    v = v_ref[...]                         # (b, h)
    b = v.shape[0]
    cv = jnp.concatenate([v, jnp.ones((b, 1), v.dtype)], axis=-1)

    s = jnp.tril(pq @ pk.T)                # diagonal block, causal inside
    out = s @ cv + pq @ z_ref[...]         # P_l + A_l Z_l
    z_ref[...] += pk.T @ cv                # Z_{l+1} = Z_l + H_l
    o_ref[...] = out


def linear_attention_pallas(phi_q: jnp.ndarray, phi_k: jnp.ndarray,
                            v: jnp.ndarray, block: int = 64,
                            interpret: bool = True) -> jnp.ndarray:
    """Causal linear attention with the 1+ denominator; single head.

    phi_q, phi_k: (n, f) feature-mapped queries/keys; v: (n, h).
    """
    n, f = phi_q.shape
    h = v.shape[-1]
    if n % block != 0:
        raise ValueError(f"n={n} not divisible by block={block}")
    t = n // block

    out = pl.pallas_call(
        _kernel,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((block, f), lambda i: (i, 0)),
            pl.BlockSpec((block, f), lambda i: (i, 0)),
            pl.BlockSpec((block, h), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, h + 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h + 1), v.dtype),
        scratch_shapes=[pltpu.VMEM((f, h + 1), jnp.float32)],
        interpret=interpret,
    )(phi_q, phi_k, v)
    return out[:, :h] / (1.0 + out[:, h])[:, None]
