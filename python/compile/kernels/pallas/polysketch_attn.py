"""The core PolySketchFormer Pallas kernel.

Causal Polysketch attention over *half-sketches* L, R (n, rs) — the outputs
of PolySketchWithNegativity at degree p/2.  The implicit feature map is the
row-wise self-tensor phi' = L^{(x)2} (Theorem 1.1), realized only:

  * in the prefix state  Z (rs^2 x (h+1)), carried in VMEM scratch, and
  * per-block as phi_q_l (b x rs^2) for the A_l Z_l product,

never as an n x rs^2 tensor in HBM.  The diagonal block exploits
phi'(Q)_l phi'(K)_l^T = (L_l R_l^T)^2 (Section 3.1's observation) so block
scores cost O(b^2 rs), or — with ``local_exact`` — uses the exact polynomial
weights lt((Q_l K_l^T)^p) of Section 3.2.

VMEM residency per step (f32 words): 2*b*rs (L,R) + b*h (V) + rs^2*(h+1) (Z)
+ b*rs^2 (phi_q) + b*b (scores).  With the paper's r=32, b=1024, h=64 this
is ~4.6 MiB <= 16 MiB VMEM; the DESIGN.md §5 roofline uses these shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...common import layernorm


def _self_tensor(m: jnp.ndarray) -> jnp.ndarray:
    return (m[:, :, None] * m[:, None, :]).reshape(m.shape[0], m.shape[1] ** 2)


def _kernel_sketch(l_ref, r_ref, v_ref, o_ref, z_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    lb = l_ref[...]                          # (b, rs)
    rb = r_ref[...]
    v = v_ref[...]                           # (b, h)
    b = v.shape[0]
    cv = jnp.concatenate([v, jnp.ones((b, 1), v.dtype)], axis=-1)

    s = jnp.tril((lb @ rb.T) ** 2)           # (L R^T)^2: no phi' materialized
    phi_q = _self_tensor(lb)                 # (b, rs^2)
    out = s @ cv + phi_q @ z_ref[...]
    z_ref[...] += _self_tensor(rb).T @ cv
    o_ref[...] = out


def _kernel_local(l_ref, r_ref, v_ref, q_ref, k_ref, o_ref, z_ref, *, p: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    lb = l_ref[...]
    rb = r_ref[...]
    v = v_ref[...]
    b = v.shape[0]
    cv = jnp.concatenate([v, jnp.ones((b, 1), v.dtype)], axis=-1)

    # Section 3.2: exact degree-p polynomial weights inside the local block.
    s = jnp.tril((q_ref[...] @ k_ref[...].T) ** p)
    phi_q = _self_tensor(lb)
    out = s @ cv + phi_q @ z_ref[...]
    z_ref[...] += _self_tensor(rb).T @ cv
    o_ref[...] = out


def polysketch_attention_pallas(l: jnp.ndarray, r: jnp.ndarray, v: jnp.ndarray,
                                block: int = 64,
                                q: jnp.ndarray | None = None,
                                k: jnp.ndarray | None = None,
                                p: int = 4,
                                local_exact: bool = False,
                                interpret: bool = True) -> jnp.ndarray:
    """Causal Polysketch attention; single (batch, head) slice.

    l, r: (n, rs) half-sketches of Q and K; v: (n, h) values.
    With ``local_exact``, q/k are the raw (n, h) queries/keys (layer norm is
    applied here, matching ref.polysketch_attention).
    """
    n, rs = l.shape
    h = v.shape[-1]
    if n % block != 0:
        raise ValueError(f"n={n} not divisible by block={block}")
    t = n // block

    common = dict(
        grid=(t,),
        out_specs=pl.BlockSpec((block, h + 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h + 1), v.dtype),
        scratch_shapes=[pltpu.VMEM((rs * rs, h + 1), jnp.float32)],
        interpret=interpret,
    )
    spec_lr = pl.BlockSpec((block, rs), lambda i: (i, 0))
    spec_v = pl.BlockSpec((block, h), lambda i: (i, 0))

    if local_exact:
        if q is None or k is None:
            raise ValueError("local_exact needs raw q, k")
        qn, kn = layernorm(q), layernorm(k)
        spec_qk = pl.BlockSpec((block, h), lambda i: (i, 0))
        import functools
        out = pl.pallas_call(
            functools.partial(_kernel_local, p=p),
            in_specs=[spec_lr, spec_lr, spec_v, spec_qk, spec_qk],
            **common,
        )(l, r, v, qn, kn)
    else:
        out = pl.pallas_call(
            _kernel_sketch,
            in_specs=[spec_lr, spec_lr, spec_v],
            **common,
        )(l, r, v)
    return out[:, :h] / (1.0 + out[:, h])[:, None]
