"""L1 kernels: polynomial sketches, attention oracles, block-lt scan
implementations, and the Pallas kernels (in ``kernels.pallas``)."""

from . import ref, sketch, linear_attn

__all__ = ["ref", "sketch", "linear_attn"]
