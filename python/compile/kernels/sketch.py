"""Algorithm 1 of the paper: polynomial sketches.

``polysketch_with_negativity``  — recursive Gaussian sketch computing
    A^{(x)p} S for the Ahle et al. (2020) sketch S (Theorem 2.2).
``polysketch_nonnegative``      — our reproduction of the paper's
    non-negative feature map phi'(A) = (A^{(x)p/2} S)^{(x)2} (Theorem 1.1).

The sketches are *functional*: the Gaussian projection matrices are passed
in explicitly so the same matrices can be (a) shared between Q and K —
required for correctness, (b) replaced by learned transformations
(Algorithm 2, see sketch_layers.py), and (c) re-materialized bit-exactly on
the rust side.
"""

from __future__ import annotations

import math
from typing import List, Sequence

import jax
import jax.numpy as jnp

from ..common import self_tensor


def num_projections(p: int) -> int:
    """Number of Gaussian matrices PolySketchWithNegativity(., r, p) consumes.

    count(1) = 0; count(p) = 2*count(p/2) + 2  =>  count(p) = 2(p - 1).
    The paper's phi' of degree p calls the recursion at degree p/2, consuming
    p - 2 matrices — matching "only (p-2) random projections" (Section 2.3).
    """
    if p == 1:
        return 0
    _require_pow2(p)
    return 2 * num_projections(p // 2) + 2


def projection_shapes(h: int, r: int, p: int) -> List[tuple]:
    """Shapes of the Gaussian matrices, in consumption order.

    Leaf-level projections (applied to the raw h-dim rows) are (h, r); all
    higher recursion levels project r-dim intermediates, hence (r, r).
    """
    if p == 1:
        return []
    _require_pow2(p)
    sub = projection_shapes(h, r, p // 2)
    inner = h if p == 2 else r
    return sub + sub + [(inner, r), (inner, r)]


def sample_projections(key: jax.Array, h: int, r: int, p: int) -> List[jnp.ndarray]:
    """Draw the standard-Gaussian projection stack for degree p."""
    shapes = projection_shapes(h, r, p)
    keys = jax.random.split(key, max(len(shapes), 1))
    return [jax.random.normal(kk, s, dtype=jnp.float32) for kk, s in zip(keys, shapes)]


def polysketch_with_negativity(a: jnp.ndarray, gs: Sequence[jnp.ndarray],
                               r: int, p: int) -> jnp.ndarray:
    """PolySketchWithNegativity(A, r, p): returns A^{(x)p} S, shape (n, r).

    Recursive construction of Theorem 2.2: for p = 2,
        A^{(x)2} S = sqrt(1/r) (A G1) * (A G2);
    for larger powers of two, sketch each half then combine the r-dim
    intermediates with fresh (r, r) Gaussians and a Hadamard product.
    """
    if p == 1:
        return a
    _require_pow2(p)
    n_sub = num_projections(p // 2)
    m1 = polysketch_with_negativity(a, gs[:n_sub], r, p // 2)
    m2 = polysketch_with_negativity(a, gs[n_sub:2 * n_sub], r, p // 2)
    g1, g2 = gs[2 * n_sub], gs[2 * n_sub + 1]
    return math.sqrt(1.0 / r) * ((m1 @ g1) * (m2 @ g2))


def polysketch_nonnegative(a: jnp.ndarray, gs: Sequence[jnp.ndarray],
                           r: int, p: int) -> jnp.ndarray:
    """PolySketchNonNegative(A, r, p): phi'(A) = (A^{(x)p/2} S)^{(x)2}.

    Output shape (n, r^2); all pairwise inner products between outputs are
    squares, hence >= 0 (the self-tensoring trick, Theorem 2.4).
    """
    _require_pow2(p)
    if p < 2:
        raise ValueError("nonnegative sketch needs even p >= 2")
    m = polysketch_with_negativity(a, gs, r, p // 2)
    return self_tensor(m)


def half_sketch(a: jnp.ndarray, gs: Sequence[jnp.ndarray], r: int, p: int) -> jnp.ndarray:
    """The degree-p/2 half sketch L with phi'(a_i) = l_i (x) l_i.

    The block algorithm (Section 3.1) works directly on L and R: the
    diagonal-block score matrix is (L R^T)^2 which never materializes the
    r^2-dim features.
    """
    _require_pow2(p)
    return polysketch_with_negativity(a, gs, r, p // 2)


def _require_pow2(p: int) -> None:
    if p < 1 or (p & (p - 1)) != 0:
        raise ValueError(f"degree must be a power of two, got {p}")
