"""Differentiable block lower-triangular attention (Section 3.1/3.2).

These are the *training-path* implementations: pure jnp, autodiff-friendly,
and algorithmically identical to the Pallas kernels in ``kernels/pallas/``
(the Pallas kernels are the hand-scheduled forward versions; pytest asserts
bit-closeness between the two and against the naive oracles in ref.py).

All functions operate on a single (batch, head) slice; the model vmaps.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..common import layernorm, self_tensor


def _blockify(x: jnp.ndarray, block: int) -> jnp.ndarray:
    n = x.shape[0]
    if n % block != 0:
        raise ValueError(f"sequence length {n} not divisible by block {block}")
    return x.reshape(n // block, block, *x.shape[1:])


def block_linear_attention(phi_q: jnp.ndarray, phi_k: jnp.ndarray,
                           v: jnp.ndarray, block: int) -> jnp.ndarray:
    """Causal linear attention via block lt-multiplication.

    Computes lt(phi_q phi_k^T) [V | 1] blockwise: per-block states
    H_l = phi_k_l^T [V_l | 1], exclusive prefix Z_l = sum_{j<l} H_j, and the
    diagonal contribution P_l = lt(phi_q_l phi_k_l^T) [V_l | 1].  The value
    matrix and the denominator's all-ones column ride in one state so a
    single prefix pass produces both numerator and normalizer.
    """
    n, h = v.shape
    aq, ak, av = _blockify(phi_q, block), _blockify(phi_k, block), _blockify(v, block)
    cv = jnp.concatenate([av, jnp.ones((*av.shape[:-1], 1), av.dtype)], axis=-1)
    s = jnp.einsum("tbf,tcf->tbc", aq, ak)
    s = jnp.tril(s)
    p_diag = jnp.einsum("tbc,tch->tbh", s, cv)
    hs = jnp.einsum("tcf,tch->tfh", ak, cv)           # H_l
    z = jnp.cumsum(hs, axis=0) - hs                   # exclusive prefix Z_l
    out = p_diag + jnp.einsum("tbf,tfh->tbh", aq, z)
    out = out.reshape(n, h + 1)
    return out[:, :h] / (1.0 + out[:, h])[:, None]


def block_polysketch_attention(l: jnp.ndarray, r: jnp.ndarray, v: jnp.ndarray,
                               block: int,
                               q: jnp.ndarray | None = None,
                               k: jnp.ndarray | None = None,
                               p: int = 4,
                               local_exact: bool = False) -> jnp.ndarray:
    """Polysketch attention on half-sketches L, R (n, rs).

    Off-diagonal blocks use the implicit self-tensored features
    phi' = L^{(x)2} via the r^2-dim prefix state; the diagonal block score is
    (L_l R_l^T)^2 which never materializes phi' (Section 3.1's observation).
    With ``local_exact`` the diagonal block instead uses the exact
    degree-p polynomial weights lt((Q_l K_l^T)^p) (Section 3.2).
    """
    n, h = v.shape
    rs = l.shape[-1]
    lb, rb, vb = _blockify(l, block), _blockify(r, block), _blockify(v, block)
    cv = jnp.concatenate([vb, jnp.ones((*vb.shape[:-1], 1), vb.dtype)], axis=-1)

    if local_exact:
        if q is None or k is None:
            raise ValueError("local_exact needs raw q, k")
        qb, kb = _blockify(layernorm(q), block), _blockify(layernorm(k), block)
        s = jnp.einsum("tbd,tcd->tbc", qb, kb) ** p
    else:
        s = jnp.einsum("tbr,tcr->tbc", lb, rb) ** 2
    s = jnp.tril(s)
    p_diag = jnp.einsum("tbc,tch->tbh", s, cv)

    phi_k = self_tensor(rb)                            # (t, b, rs^2)
    phi_q = self_tensor(lb)
    hs = jnp.einsum("tcf,tch->tfh", phi_k, cv)
    z = jnp.cumsum(hs, axis=0) - hs
    out = p_diag + jnp.einsum("tbf,tfh->tbh", phi_q, z)
    out = out.reshape(n, h + 1)
    del rs
    return out[:, :h] / (1.0 + out[:, h])[:, None]
