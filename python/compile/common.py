"""Shared numerical helpers for the compile-time (L1/L2) Python stack.

Everything in ``python/`` runs only at build time (``make artifacts``); the
rust coordinator never imports it.
"""

from __future__ import annotations

import jax.numpy as jnp

# Epsilon used by all layer norms in the stack (matches the rust side).
LN_EPS = 1e-6


def layernorm(x: jnp.ndarray, eps: float = LN_EPS) -> jnp.ndarray:
    """Parameter-free layer normalization over the last axis.

    The paper (Section 2.1) applies layer normalization to query and key
    vectors before the polynomial attention so that ``<q, k> + alpha`` can be
    absorbed into a rescale-and-bias of mean-zero vectors.  Learned
    scale/bias, when needed, are applied by the caller.
    """
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps)


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approximation GELU (matches the rust-side implementation)."""
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, dtype=x.dtype))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def self_tensor(m: jnp.ndarray) -> jnp.ndarray:
    """Row-wise self Kronecker product: each row a -> a (x) a.

    For ``m`` of shape (..., r) returns shape (..., r*r).  This is the
    "self-tensoring" trick of Theorem 2.4 that makes the sketched attention
    weights provably non-negative.
    """
    return (m[..., :, None] * m[..., None, :]).reshape(*m.shape[:-1], m.shape[-1] ** 2)
